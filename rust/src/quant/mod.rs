//! Quantizers: group-wise uniform (RTN core), bit packing, second-round
//! scale/zero quantization (SpQR), binarization with residual approximation
//! (BiLLM), sensitivity-weighted non-uniform k-means (SqueezeLLM-lite), and
//! average-bit accounting.

pub mod binary;
pub mod nonuniform;
pub mod packing;
pub mod scale_quant;
pub mod uniform;

use crate::tensor::Mat;

/// Bit-budget accounting for one quantized weight matrix, mirroring SpQR's
/// "average bits" metric (paper Tables 1-2 column "Avg Bits"):
/// weight bits + amortized group parameters + FP32 outliers with sparse
/// 16-bit column indices.
#[derive(Debug, Clone, Default)]
pub struct BitBudget {
    pub weight_elems: usize,
    pub weight_bits: usize,
    /// Total bits spent on scales/zeros (after any second-round quant).
    pub param_bits: usize,
    /// Number of FP32 outliers kept aside.
    pub outliers: usize,
}

impl BitBudget {
    /// Average bits per original weight element.
    pub fn avg_bits(&self) -> f64 {
        if self.weight_elems == 0 {
            return 0.0;
        }
        let outlier_bits = self.outliers * (32 + 16); // value + column index
        let dense_bits = self.weight_elems * self.weight_bits;
        (dense_bits + self.param_bits + outlier_bits) as f64 / self.weight_elems as f64
    }

    pub fn merge(&mut self, other: &BitBudget) {
        self.weight_elems += other.weight_elems;
        // Weighted by elements; keep the max nominal width for reporting.
        self.weight_bits = self.weight_bits.max(other.weight_bits);
        self.param_bits += other.param_bits;
        self.outliers += other.outliers;
    }

    /// Merge that tracks the true average across layers of different widths.
    pub fn merged_avg(budgets: &[BitBudget]) -> f64 {
        let total_elems: usize = budgets.iter().map(|b| b.weight_elems).sum();
        if total_elems == 0 {
            return 0.0;
        }
        let total_bits: f64 = budgets
            .iter()
            .map(|b| b.avg_bits() * b.weight_elems as f64)
            .sum();
        total_bits / total_elems as f64
    }
}

/// Output of quantizing one linear layer: the dequantized weights the model
/// will run with, plus accounting + error stats.
#[derive(Debug, Clone)]
pub struct QuantizedLayer {
    pub name: String,
    pub dq: Mat,
    pub budget: BitBudget,
    /// tr(dW H dW^T) proxy error the calibration minimized (diagnostics).
    pub calib_error: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avg_bits_plain_2bit_grouped() {
        // 128x128 weights, group 16, fp16 scale+zero per group, no outliers.
        let elems = 128 * 128;
        let groups = elems / 16;
        let b = BitBudget {
            weight_elems: elems,
            weight_bits: 2,
            param_bits: groups * 32,
            outliers: 0,
        };
        assert!((b.avg_bits() - 4.0).abs() < 1e-9); // 2 + 32/16
    }

    #[test]
    fn outliers_cost_48_bits() {
        let b = BitBudget { weight_elems: 100, weight_bits: 2, param_bits: 0, outliers: 1 };
        assert!((b.avg_bits() - (2.0 + 48.0 / 100.0)).abs() < 1e-9);
    }

    #[test]
    fn merged_avg_weighted() {
        let a = BitBudget { weight_elems: 100, weight_bits: 2, param_bits: 0, outliers: 0 };
        let c = BitBudget { weight_elems: 300, weight_bits: 4, param_bits: 0, outliers: 0 };
        let avg = BitBudget::merged_avg(&[a, c]);
        assert!((avg - 3.5).abs() < 1e-9);
    }
}

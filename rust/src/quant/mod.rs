//! Quantizers: group-wise uniform (RTN core), bit packing, second-round
//! scale/zero quantization (SpQR), binarization with residual approximation
//! (BiLLM), sensitivity-weighted non-uniform k-means (SqueezeLLM-lite),
//! per-group symmetric int8 activation quantization for integer-domain
//! serving ([`act_quant`]), average-bit accounting, and the [`PackSpec`]
//! declaration each calibration backend publishes for the packed serving
//! export.

pub mod act_quant;
pub mod binary;
pub mod nonuniform;
pub mod packing;
pub mod scale_quant;
pub mod uniform;

use crate::calib::CalibConfig;
use crate::quant::uniform::GroupParams;
use crate::tensor::Mat;

/// Recover the affine export grid of a backend from the *original*
/// (pre-quantization) weights — must be a pure function of `(w, cfg)` so the
/// serve exporter can regenerate exactly the grid calibration quantized
/// against.
pub type GridFn = fn(&Mat, &CalibConfig) -> Vec<GroupParams>;

/// How a backend's calibrated output is exported into the packed serving
/// store ([`crate::serve::PackedModel::from_quantized`]). Declared by each
/// [`crate::calib::CalibBackend`] via `pack_spec()`, so the serve exporter
/// needs no per-backend knowledge: it packs purely from the spec.
///
/// Every scheme is **bit-exact**: decoding the packed layer reproduces the
/// calibrated weights bit-for-bit (non-representable residues are kept as
/// sparse FP32 overrides).
#[derive(Clone, Copy, Debug)]
pub enum PackSpec {
    /// Group-wise affine codes recovered against `grid(original_w, cfg)` —
    /// the RTN/SpQR family, whose group grid is a pure function of the
    /// original weights.
    AffineGrid { grid: GridFn },
    /// Two-plane residual binarization with per-row `(α₁, α₂)`
    /// ([`crate::serve::encode_binary_calibrated`]).
    BinaryPlanes,
    /// Universal exact capture: per-row codebook of up to 2^16 distinct f32
    /// levels (u8 codes through 256 levels, u16 codes beyond). The fallback
    /// for backends whose grid is not recoverable after calibration (OPTQ's
    /// dynamic groups, QuIP's rotated space); fails cleanly on rows with
    /// more distinct values than a u16 code addresses.
    Codebook,
}

impl PackSpec {
    /// Short scheme label for the `oac backends` listing.
    pub fn label(&self) -> &'static str {
        match self {
            PackSpec::AffineGrid { .. } => "affine-grid",
            PackSpec::BinaryPlanes => "binary-planes",
            PackSpec::Codebook => "codebook",
        }
    }
}

/// Bit-budget accounting for one quantized weight matrix, mirroring SpQR's
/// "average bits" metric (paper Tables 1-2 column "Avg Bits"):
/// weight bits + amortized group parameters + FP32 outliers with sparse
/// 16-bit column indices.
#[derive(Debug, Clone, Default)]
pub struct BitBudget {
    pub weight_elems: usize,
    pub weight_bits: usize,
    /// Total bits spent on scales/zeros (after any second-round quant).
    pub param_bits: usize,
    /// Number of FP32 outliers kept aside.
    pub outliers: usize,
}

impl BitBudget {
    /// Average bits per original weight element.
    pub fn avg_bits(&self) -> f64 {
        if self.weight_elems == 0 {
            return 0.0;
        }
        let outlier_bits = self.outliers * (32 + 16); // value + column index
        let dense_bits = self.weight_elems * self.weight_bits;
        (dense_bits + self.param_bits + outlier_bits) as f64 / self.weight_elems as f64
    }

    pub fn merge(&mut self, other: &BitBudget) {
        self.weight_elems += other.weight_elems;
        // Weighted by elements; keep the max nominal width for reporting.
        self.weight_bits = self.weight_bits.max(other.weight_bits);
        self.param_bits += other.param_bits;
        self.outliers += other.outliers;
    }

    /// Merge that tracks the true average across layers of different widths.
    pub fn merged_avg(budgets: &[BitBudget]) -> f64 {
        let total_elems: usize = budgets.iter().map(|b| b.weight_elems).sum();
        if total_elems == 0 {
            return 0.0;
        }
        let total_bits: f64 = budgets
            .iter()
            .map(|b| b.avg_bits() * b.weight_elems as f64)
            .sum();
        total_bits / total_elems as f64
    }
}

/// Output of quantizing one linear layer: the dequantized weights the model
/// will run with, plus accounting + error stats.
#[derive(Debug, Clone)]
pub struct QuantizedLayer {
    pub name: String,
    pub dq: Mat,
    pub budget: BitBudget,
    /// tr(dW H dW^T) proxy error the calibration minimized (diagnostics).
    pub calib_error: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avg_bits_plain_2bit_grouped() {
        // 128x128 weights, group 16, fp16 scale+zero per group, no outliers.
        let elems = 128 * 128;
        let groups = elems / 16;
        let b = BitBudget {
            weight_elems: elems,
            weight_bits: 2,
            param_bits: groups * 32,
            outliers: 0,
        };
        assert!((b.avg_bits() - 4.0).abs() < 1e-9); // 2 + 32/16
    }

    #[test]
    fn outliers_cost_48_bits() {
        let b = BitBudget { weight_elems: 100, weight_bits: 2, param_bits: 0, outliers: 1 };
        assert!((b.avg_bits() - (2.0 + 48.0 / 100.0)).abs() < 1e-9);
    }

    #[test]
    fn merged_avg_weighted() {
        let a = BitBudget { weight_elems: 100, weight_bits: 2, param_bits: 0, outliers: 0 };
        let c = BitBudget { weight_elems: 300, weight_bits: 4, param_bits: 0, outliers: 0 };
        let avg = BitBudget::merged_avg(&[a, c]);
        assert!((avg - 3.5).abs() < 1e-9);
    }
}

//! Sensitivity-weighted non-uniform quantization (SqueezeLLM-lite).
//!
//! SqueezeLLM (Kim et al., ICML 2024) clusters each row's weights with
//! k-means weighted by the diagonal Fisher sensitivity — no calibration
//! updates, non-uniform codebook of 2^bits centroids per row. The paper
//! includes it as a 3-bit baseline (Table 13); this is the same algorithm
//! at our scale (weighted 1-D k-means via Lloyd iterations).

use crate::tensor::Mat;

/// Weighted 1-D k-means: returns centroids and assignment-dequantized values.
pub fn weighted_kmeans_1d(
    vals: &[f32],
    weights: &[f32],
    k: usize,
    iters: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(vals.len(), weights.len());
    if vals.is_empty() {
        return (vec![], vec![]);
    }
    // Init: quantiles of the sorted values.
    let mut sorted: Vec<f32> = vals.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut centroids: Vec<f32> = (0..k)
        .map(|i| sorted[((i * 2 + 1) * sorted.len() / (2 * k)).min(sorted.len() - 1)])
        .collect();
    centroids.dedup();
    while centroids.len() < k {
        centroids.push(*centroids.last().unwrap() + 1e-3);
    }

    let mut assign = vec![0usize; vals.len()];
    for _ in 0..iters {
        // Assign (centroids stay sorted, binary search would work; linear k
        // is fine for k <= 16).
        for (i, &v) in vals.iter().enumerate() {
            let mut best = 0;
            let mut bd = f32::INFINITY;
            for (c, &ct) in centroids.iter().enumerate() {
                let d = (v - ct).abs();
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            assign[i] = best;
        }
        // Update (sensitivity-weighted mean).
        let mut num = vec![0.0f64; k];
        let mut den = vec![0.0f64; k];
        for (i, &a) in assign.iter().enumerate() {
            let w = weights[i].max(1e-12) as f64;
            num[a] += w * vals[i] as f64;
            den[a] += w;
        }
        for c in 0..k {
            if den[c] > 0.0 {
                centroids[c] = (num[c] / den[c]) as f32;
            }
        }
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
    let dq = assign.iter().map(|&a| centroids[a]).collect();
    (centroids, dq)
}

/// SqueezeLLM-lite on a weight matrix: per-row weighted k-means with the
/// Hessian diagonal as the sensitivity (diag of X^T X or of Σ G^T G).
pub fn squeeze_quantize(w: &Mat, hessian_diag: &[f32], bits: usize) -> Mat {
    assert_eq!(hessian_diag.len(), w.cols);
    let k = 1usize << bits;
    let mut out = w.clone();
    for r in 0..w.rows {
        let (_, dq) = weighted_kmeans_1d(w.row(r), hessian_diag, k, 12);
        out.row_mut(r).copy_from_slice(&dq);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn kmeans_recovers_clusters() {
        let mut rng = Rng::new(0);
        let mut vals = Vec::new();
        for _ in 0..50 {
            vals.push(-1.0 + rng.normal_f32() * 0.01);
        }
        for _ in 0..50 {
            vals.push(2.0 + rng.normal_f32() * 0.01);
        }
        let w = vec![1.0f32; 100];
        let (centroids, dq) = weighted_kmeans_1d(&vals, &w, 2, 10);
        assert!((centroids[0] - -1.0).abs() < 0.05, "{centroids:?}");
        assert!((centroids[1] - 2.0).abs() < 0.05, "{centroids:?}");
        let err: f32 = vals.iter().zip(&dq).map(|(v, d)| (v - d).powi(2)).sum();
        assert!(err / 100.0 < 1e-3);
    }

    #[test]
    fn sensitivity_pulls_centroids() {
        // Two values, one with huge sensitivity: the 1-centroid solution
        // lands (almost) on the sensitive one.
        let vals = [0.0f32, 1.0];
        let (c, _) = weighted_kmeans_1d(&vals, &[1.0, 1000.0], 1, 20);
        assert!(c[0] > 0.95, "{c:?}");
    }

    #[test]
    fn nonuniform_beats_uniform_on_skewed() {
        // Log-normal-ish magnitudes: non-uniform codebooks win.
        let mut rng = Rng::new(1);
        let mut w = Mat::zeros(4, 256);
        for v in w.data.iter_mut() {
            *v = (rng.normal_f32() * 1.5).exp() * if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        }
        let diag = vec![1.0f32; 256];
        let nu = squeeze_quantize(&w, &diag, 3);
        let un = crate::quant::uniform::qdq_mat(&w, 256, 3);
        assert!(nu.mse(&w) < un.mse(&w));
    }

    #[test]
    fn codebook_size_respected() {
        let mut rng = Rng::new(2);
        let mut w = Mat::zeros(2, 128);
        rng.fill_normal(&mut w.data, 1.0);
        let dq = squeeze_quantize(&w, &vec![1.0; 128], 2);
        for r in 0..2 {
            let mut vals: Vec<f32> = dq.row(r).to_vec();
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup_by(|a, b| (*a - *b).abs() < 1e-7);
            assert!(vals.len() <= 4);
        }
    }
}

//! Per-group symmetric integer activation quantization — the activation
//! half of the integer-domain serving path, at 8 or 4 bits.
//!
//! An activation matrix `x` (K rows = the weight's input dim, N cols = the
//! request batch) is quantized per (K-group, column): each group of a
//! column gets one symmetric scale `amax / qmax` (`qmax` = 127 at 8 bits,
//! 7 at 4) and codes `round(x / scale)` clamped to ±qmax. Grouping along K
//! mirrors the weight grid — a uniform-scheme layer quantizes activations
//! with its own weight `group_size`, so one `(weight scale × activation
//! scale)` product per group turns the group's i32 code dot straight into
//! f32 output ([`crate::serve::PackedLinear::forward_int8_with`]).
//!
//! The codes are stored twice, in the layouts the integer kernels want:
//! row-major i8 (`q8` — the codebook LUT walk and the sparse-outlier f32
//! epilogue, at either bit width) plus the dense-dot operand for the
//! selected width — transposed i16 codes (`qt`, the
//! [`crate::tensor::igemm::idot`] operand) at 8 bits, or nibble-packed
//! transposed codes (`q4t`, the paired-nibble `idot4` operand; low nibble
//! first, each (column, K-group) cell byte-aligned so cell slices line up
//! with the weight group grid) at 4 bits. Per-group code sums (`gsums`)
//! are precomputed once so the uniform epilogue's zero-point correction
//! costs one multiply per output cell.
//!
//! Quantization happens once per layer application, before any worker
//! fan-out, so every panel worker reads the same codes — thread-invariance
//! of the int8 forward needs no further argument from this module.

use crate::tensor::Mat;
use crate::util::pool::chunk_ranges;

/// K-group width used when the weight scheme has no column grouping of its
/// own (binary planes, codebooks). Small enough that group i32 dots stay
/// exact in f32 conversion, large enough to amortize the per-group epilogue.
pub const DEFAULT_ACT_GROUP: usize = 64;

/// One activation matrix quantized to int8 or int4, in the layouts the
/// integer kernels consume. Reusable: [`quantize_into_bits`] resizes
/// without reallocating once buffers reach their high-water mark.
#[derive(Debug, Clone, Default)]
pub struct QuantizedActs {
    /// K — the quantized matrix's row count (= weight cols).
    pub rows: usize,
    /// N — batch width.
    pub cols: usize,
    /// K-group size (the last group may be ragged).
    pub group: usize,
    /// Code bit width: 8 or 4.
    pub bits: usize,
    /// Transposed, i16-widened codes: `qt[j * rows + c]` is the code of
    /// `x[c, j]`. One contiguous K-slice per batch column — the `idot`
    /// operand. Populated only at `bits == 8`.
    pub qt: Vec<i16>,
    /// Nibble-packed transposed codes (`bits == 4` only): per column, per
    /// K-group, the group's codes as 4-bit two's-complement nibbles, low
    /// nibble first, zero-padded to whole bytes per group. The cell for
    /// (column `j`, group `g`) is
    /// `q4t[j * q4_stride() + q4_off[g] .. j * q4_stride() + q4_off[g+1]]`
    /// — the `idot4` operand.
    pub q4t: Vec<u8>,
    /// Per-group byte offsets within one column's `q4t` block
    /// (`n_groups + 1` entries; empty unless `bits == 4`).
    pub q4_off: Vec<usize>,
    /// Row-major i8 codes, same layout as `x.data`: `q8[c * cols + j]`.
    /// Populated at every bit width (int4 codes fit i8).
    pub q8: Vec<i8>,
    /// Per-(group, column) symmetric scale, `scales[g * cols + j]`;
    /// 0.0 for all-zero (or non-finite) groups, whose codes are all 0.
    pub scales: Vec<f32>,
    /// Per-(group, column) code sums — the uniform scheme's zero-point
    /// correction term.
    pub gsums: Vec<i32>,
}

impl QuantizedActs {
    /// Number of K-groups.
    pub fn n_groups(&self) -> usize {
        self.rows.div_ceil(self.group)
    }

    /// Dequantized activation at `(c, j)` — `scale * code`, the value the
    /// integer kernels effectively multiply weights by.
    pub fn dequant_at(&self, c: usize, j: usize) -> f32 {
        let g = c / self.group;
        self.scales[g * self.cols + j] * self.q8[c * self.cols + j] as f32
    }

    /// Bytes per column of the nibble-packed layout (`bits == 4` only).
    pub fn q4_stride(&self) -> usize {
        self.q4_off.last().copied().unwrap_or(0)
    }
}

/// Quantize `x` into `out` with K-groups of `group` rows at `bits` ∈
/// {8, 4}. Deterministic in `(x, group, bits)`; buffers in `out` are
/// reused across calls. The dense-dot operand layout follows the bit
/// width: `qt` at 8 bits, `q4t`/`q4_off` at 4 (the other stays empty).
pub fn quantize_into_bits(x: &Mat, group: usize, bits: usize, out: &mut QuantizedActs) {
    assert!(group > 0, "activation group must be positive");
    assert!(bits == 8 || bits == 4, "activation bits {bits} unsupported (8 or 4)");
    let (k, n) = (x.rows, x.cols);
    let groups = chunk_ranges(k, group);
    let qmax = ((1i32 << (bits - 1)) - 1) as f32; // 127 or 7, symmetric
    out.rows = k;
    out.cols = n;
    out.group = group;
    out.bits = bits;
    resize(&mut out.q8, k * n);
    resize(&mut out.scales, groups.len() * n);
    resize(&mut out.gsums, groups.len() * n);

    for (g, gr) in groups.iter().enumerate() {
        let scales = &mut out.scales[g * n..(g + 1) * n];
        scales.fill(0.0);
        for c in gr.clone() {
            for (s, &v) in scales.iter_mut().zip(&x.data[c * n..(c + 1) * n]) {
                let a = v.abs();
                if a > *s {
                    *s = a;
                }
            }
        }
        for s in scales.iter_mut() {
            *s = if *s > 0.0 && s.is_finite() { *s / qmax } else { 0.0 };
        }
        let gsums = &mut out.gsums[g * n..(g + 1) * n];
        gsums.fill(0);
        for c in gr.clone() {
            let xrow = &x.data[c * n..(c + 1) * n];
            let qrow = &mut out.q8[c * n..(c + 1) * n];
            for j in 0..n {
                let s = scales[j];
                let q = if s > 0.0 {
                    (xrow[j] / s).round().clamp(-qmax, qmax) as i32
                } else {
                    0
                };
                qrow[j] = q as i8;
                gsums[j] += q;
            }
        }
    }
    // Second pass: the dense-dot operand (contiguous writes per column).
    if bits == 8 {
        resize(&mut out.qt, k * n);
        resize(&mut out.q4t, 0);
        resize(&mut out.q4_off, 0);
        for j in 0..n {
            let qt = &mut out.qt[j * k..(j + 1) * k];
            for (c, slot) in qt.iter_mut().enumerate() {
                *slot = out.q8[c * n + j] as i16;
            }
        }
    } else {
        resize(&mut out.qt, 0);
        resize(&mut out.q4_off, groups.len() + 1);
        for (g, gr) in groups.iter().enumerate() {
            out.q4_off[g + 1] = out.q4_off[g] + gr.len().div_ceil(2);
        }
        let stride = out.q4_off[groups.len()];
        resize(&mut out.q4t, n * stride);
        for j in 0..n {
            let col = &mut out.q4t[j * stride..(j + 1) * stride];
            for (g, gr) in groups.iter().enumerate() {
                let cell = &mut col[out.q4_off[g]..out.q4_off[g + 1]];
                for (bi, byte) in cell.iter_mut().enumerate() {
                    let c0 = gr.start + 2 * bi;
                    let lo = (out.q8[c0 * n + j] as u8) & 0x0F;
                    let hi = if c0 + 1 < gr.end {
                        (out.q8[(c0 + 1) * n + j] as u8) & 0x0F
                    } else {
                        0 // odd-length group: zero high nibble
                    };
                    *byte = lo | (hi << 4);
                }
            }
        }
    }
}

/// Int8 compatibility entry: [`quantize_into_bits`] at 8 bits.
pub fn quantize_into(x: &Mat, group: usize, out: &mut QuantizedActs) {
    quantize_into_bits(x, group, 8, out);
}

/// Allocating convenience wrapper around [`quantize_into_bits`].
pub fn quantize_bits(x: &Mat, group: usize, bits: usize) -> QuantizedActs {
    let mut out = QuantizedActs::default();
    quantize_into_bits(x, group, bits, &mut out);
    out
}

/// Allocating convenience wrapper around [`quantize_into`] (int8).
pub fn quantize(x: &Mat, group: usize) -> QuantizedActs {
    quantize_bits(x, group, 8)
}

fn resize<T: Clone + Default>(v: &mut Vec<T>, len: usize) {
    v.clear();
    v.resize(len, T::default());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, 1.0);
        m
    }

    #[test]
    fn codes_within_half_step_and_range() {
        let mut rng = Rng::new(0);
        let x = randmat(&mut rng, 70, 5); // ragged last group at group=32
        let acts = quantize(&x, 32);
        assert_eq!(acts.n_groups(), 3);
        for c in 0..x.rows {
            for j in 0..x.cols {
                let q = acts.q8[c * x.cols + j];
                assert!((-127..=127).contains(&(q as i32)));
                assert_eq!(acts.qt[j * x.rows + c], q as i16, "layouts disagree");
                let err = (x.at(c, j) - acts.dequant_at(c, j)).abs();
                let g = c / 32;
                let s = acts.scales[g * x.cols + j];
                assert!(err <= 0.5 * s * 1.0001 + 1e-7, "({c},{j}): err {err} scale {s}");
            }
        }
    }

    #[test]
    fn gsums_match_code_sums() {
        let mut rng = Rng::new(1);
        let x = randmat(&mut rng, 48, 4);
        let acts = quantize(&x, 16);
        for g in 0..acts.n_groups() {
            for j in 0..x.cols {
                let want: i32 = (g * 16..(g + 1) * 16)
                    .map(|c| acts.q8[c * x.cols + j] as i32)
                    .sum();
                assert_eq!(acts.gsums[g * x.cols + j], want, "({g},{j})");
            }
        }
    }

    #[test]
    fn zero_group_has_zero_scale_and_codes() {
        let mut x = Mat::zeros(32, 3);
        // First group of column 1 nonzero; everything else zero.
        *x.at_mut(3, 1) = 2.5;
        let acts = quantize(&x, 16);
        assert_eq!(acts.scales[0], 0.0); // (g0, j0)
        assert!(acts.scales[1] > 0.0); // (g0, j1)
        assert_eq!(acts.scales[3 + 1], 0.0); // (g1, j1)
        assert!(acts.q8.iter().enumerate().all(|(i, &q)| q == 0 || i == 3 * 3 + 1));
        assert_eq!(acts.q8[3 * 3 + 1], 127);
    }

    #[test]
    fn reuse_resizes_cleanly() {
        let mut rng = Rng::new(2);
        let mut acts = QuantizedActs::default();
        quantize_into(&randmat(&mut rng, 64, 8), 16, &mut acts);
        let big = acts.qt.len();
        quantize_into(&randmat(&mut rng, 16, 2), 16, &mut acts);
        assert_eq!(acts.qt.len(), 32);
        assert!(big > acts.qt.len());
        let x = randmat(&mut rng, 16, 2);
        quantize_into(&x, 16, &mut acts);
        let fresh = quantize(&x, 16);
        assert_eq!(acts.q8, fresh.q8);
        assert_eq!(acts.qt, fresh.qt);
        assert_eq!(acts.scales, fresh.scales);
        assert_eq!(acts.gsums, fresh.gsums);
    }

    #[test]
    fn int4_codes_within_half_step_and_range() {
        let mut rng = Rng::new(3);
        let x = randmat(&mut rng, 70, 5); // ragged last group at group=32
        let acts = quantize_bits(&x, 32, 4);
        assert_eq!(acts.bits, 4);
        assert!(acts.qt.is_empty(), "qt must stay empty at 4 bits");
        for c in 0..x.rows {
            for j in 0..x.cols {
                let q = acts.q8[c * x.cols + j] as i32;
                assert!((-7..=7).contains(&q), "({c},{j}): code {q}");
                let err = (x.at(c, j) - acts.dequant_at(c, j)).abs();
                let s = acts.scales[(c / 32) * x.cols + j];
                assert!(err <= 0.5 * s * 1.0001 + 1e-7, "({c},{j}): err {err} scale {s}");
            }
        }
    }

    #[test]
    fn int4_nibble_layout_round_trips() {
        // Unpacking every (column, group) cell of q4t with sign extension
        // must reproduce q8 exactly — including the ragged last group.
        let mut rng = Rng::new(4);
        for (k, group) in [(70usize, 32usize), (64, 16), (33, 64), (7, 3)] {
            let x = randmat(&mut rng, k, 4);
            let acts = quantize_bits(&x, group, 4);
            let groups = chunk_ranges(k, group);
            assert_eq!(acts.q4_off.len(), groups.len() + 1);
            let stride = acts.q4_stride();
            for j in 0..x.cols {
                for (g, gr) in groups.iter().enumerate() {
                    let cell = &acts.q4t
                        [j * stride + acts.q4_off[g]..j * stride + acts.q4_off[g + 1]];
                    assert_eq!(cell.len(), gr.len().div_ceil(2), "cell bytes");
                    for (i, c) in gr.clone().enumerate() {
                        let nib = if i % 2 == 0 { cell[i / 2] & 0x0F } else { cell[i / 2] >> 4 };
                        let got = ((nib as i8) << 4 >> 4) as i32;
                        assert_eq!(
                            got,
                            acts.q8[c * x.cols + j] as i32,
                            "k={k} group={group} ({c},{j})"
                        );
                    }
                    if gr.len() % 2 == 1 {
                        assert_eq!(cell[cell.len() - 1] >> 4, 0, "odd-tail pad nibble");
                    }
                }
            }
        }
    }

    #[test]
    fn bit_width_switch_reuses_buffers_cleanly() {
        let mut rng = Rng::new(5);
        let x = randmat(&mut rng, 64, 3);
        let mut acts = QuantizedActs::default();
        quantize_into_bits(&x, 16, 4, &mut acts);
        quantize_into_bits(&x, 16, 8, &mut acts);
        let fresh8 = quantize_bits(&x, 16, 8);
        assert_eq!(acts.qt, fresh8.qt);
        assert!(acts.q4t.is_empty() && acts.q4_off.is_empty());
        quantize_into_bits(&x, 16, 4, &mut acts);
        let fresh4 = quantize_bits(&x, 16, 4);
        assert_eq!(acts.q4t, fresh4.q4t);
        assert_eq!(acts.q8, fresh4.q8);
        assert!(acts.qt.is_empty());
    }

    #[test]
    fn max_magnitude_maps_to_127() {
        let mut x = Mat::zeros(4, 1);
        x.data.copy_from_slice(&[1.0, -3.0, 0.5, 3.0]);
        let acts = quantize(&x, 4);
        assert_eq!(acts.q8[1], -127);
        assert_eq!(acts.q8[3], 127);
    }
}

//! Bit packing for 1-8 bit integer weight codes: little-endian bit stream,
//! the storage format the budget accounting assumes. Round-trip tested.

/// Pack integer codes (each < 2^bits) into a little-endian bit stream.
pub fn pack(codes: &[u8], bits: usize) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!((c as usize) < (1 << bits), "code {c} out of range for {bits} bits");
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits;
    }
    out
}

/// Unpack `out.len()` codes of width `bits` starting at code index
/// `code_offset`, into a caller-provided buffer. The allocation-free core
/// the packed serving path uses to fill row-panel scratch tiles without
/// materializing whole matrices.
pub fn unpack_into(packed: &[u8], bits: usize, code_offset: usize, out: &mut [u8]) {
    assert!((1..=8).contains(&bits));
    let mask = ((1u16 << bits) - 1) as u16;
    let mut bitpos = code_offset * bits;
    for slot in out.iter_mut() {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let lo = packed[byte] as u16 >> off;
        let hi = if off + bits > 8 { (packed[byte + 1] as u16) << (8 - off) } else { 0 };
        *slot = ((lo | hi) & mask) as u8;
        bitpos += bits;
    }
}

/// Unpack `n` codes of width `bits` from a little-endian bit stream.
pub fn unpack(packed: &[u8], bits: usize, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n];
    unpack_into(packed, bits, 0, &mut out);
    out
}

/// Exact storage size in bytes for n codes at the given width.
pub fn packed_size(n: usize, bits: usize) -> usize {
    (n * bits).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Rng::new(0);
        for bits in 1..=8usize {
            let n = 97; // deliberately not a multiple of 8
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let packed = pack(&codes, bits);
            assert_eq!(packed.len(), packed_size(n, bits));
            assert_eq!(unpack(&packed, bits, n), codes);
        }
    }

    #[test]
    fn density_exact() {
        // 2-bit codes: exactly 4 per byte.
        let codes = vec![3u8; 256];
        assert_eq!(pack(&codes, 2).len(), 64);
        // 3-bit: 96 codes -> 36 bytes.
        let codes = vec![5u8; 96];
        assert_eq!(pack(&codes, 3).len(), 36);
    }

    #[test]
    fn crossing_byte_boundaries() {
        // 3-bit values crossing every byte boundary pattern.
        let codes: Vec<u8> = (0..16).map(|i| (i % 8) as u8).collect();
        let packed = pack(&codes, 3);
        assert_eq!(unpack(&packed, 3, 16), codes);
    }

    #[test]
    fn prop_unpack_into_offsets() {
        // Unpacking any sub-range at any code offset matches the slice of
        // the full unpack — the invariant the row-panel serving tiles rely on.
        crate::util::prop::quick(
            "unpack_into at arbitrary offsets",
            |rng| {
                let bits = 1 + rng.below(8);
                let n = 2 + rng.below(300);
                let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
                let off = rng.below(n);
                let len = 1 + rng.below(n - off);
                (bits, codes, off, len)
            },
            |(bits, codes, off, len)| {
                let packed = pack(codes, *bits);
                let mut got = vec![0u8; *len];
                unpack_into(&packed, *bits, *off, &mut got);
                if got == codes[*off..*off + *len] {
                    Ok(())
                } else {
                    Err(format!("mismatch at offset {off} len {len}"))
                }
            },
        );
    }

    #[test]
    fn prop_roundtrip() {
        crate::util::prop::quick(
            "pack/unpack roundtrip",
            |rng| {
                let bits = 1 + rng.below(8);
                let n = 1 + rng.below(200);
                let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
                (bits, codes)
            },
            |(bits, codes)| {
                let packed = pack(codes, *bits);
                let got = unpack(&packed, *bits, codes.len());
                if got == *codes {
                    Ok(())
                } else {
                    Err("mismatch".into())
                }
            },
        );
    }
}

//! Bit packing for integer weight codes: little-endian bit stream, the
//! storage format the budget accounting assumes. Round-trip tested.
//!
//! Two code widths share one stream format: the narrow u8 path (1–8 bits,
//! the uniform/binary weight codes) and the wide u16 path (1–16 bits, the
//! codebook indices — [`pack_wide`]/[`unpack_wide_into`]). For bits ≤ 8 the
//! two paths produce identical streams, so widening a codebook never
//! changes the bytes of an existing pack file.

/// Pack integer codes (each < 2^bits) into a little-endian bit stream.
pub fn pack(codes: &[u8], bits: usize) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!((c as usize) < (1 << bits), "code {c} out of range for {bits} bits");
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits;
    }
    out
}

/// Unpack `out.len()` codes of width `bits` starting at code index
/// `code_offset`, into a caller-provided buffer. The allocation-free core
/// the packed serving path uses to fill row-panel scratch tiles without
/// materializing whole matrices.
pub fn unpack_into(packed: &[u8], bits: usize, code_offset: usize, out: &mut [u8]) {
    assert!((1..=8).contains(&bits));
    let mask = ((1u16 << bits) - 1) as u16;
    let mut bitpos = code_offset * bits;
    for slot in out.iter_mut() {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let lo = packed[byte] as u16 >> off;
        let hi = if off + bits > 8 { (packed[byte + 1] as u16) << (8 - off) } else { 0 };
        *slot = ((lo | hi) & mask) as u8;
        bitpos += bits;
    }
}

/// Unpack `n` codes of width `bits` from a little-endian bit stream.
pub fn unpack(packed: &[u8], bits: usize, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n];
    unpack_into(packed, bits, 0, &mut out);
    out
}

/// Exact storage size in bytes for n codes at the given width.
pub fn packed_size(n: usize, bits: usize) -> usize {
    (n * bits).div_ceil(8)
}

/// Pack wide integer codes (each < 2^bits, bits 1–16) into a little-endian
/// bit stream. For bits ≤ 8 the stream is byte-identical to [`pack`].
pub fn pack_wide(codes: &[u16], bits: usize) -> Vec<u8> {
    assert!((1..=16).contains(&bits));
    let total_bits = codes.len() * bits;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!((c as u32) < (1u32 << bits), "code {c} out of range for {bits} bits");
        let mut v = (c as u32) << (bitpos % 8);
        let mut byte = bitpos / 8;
        loop {
            out[byte] |= (v & 0xFF) as u8;
            v >>= 8;
            if v == 0 {
                break;
            }
            byte += 1;
        }
        bitpos += bits;
    }
    out
}

/// Unpack `out.len()` wide codes (bits 1–16) starting at code index
/// `code_offset` — the u16 twin of [`unpack_into`], used by the codebook
/// decode paths once a row holds more than 256 distinct levels.
pub fn unpack_wide_into(packed: &[u8], bits: usize, code_offset: usize, out: &mut [u16]) {
    assert!((1..=16).contains(&bits));
    let mask = (1u32 << bits) - 1;
    let mut bitpos = code_offset * bits;
    for slot in out.iter_mut() {
        let mut byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = (packed[byte] as u32) >> off;
        let mut got = 8 - off;
        while got < bits {
            byte += 1;
            v |= (packed[byte] as u32) << got;
            got += 8;
        }
        *slot = (v & mask) as u16;
        bitpos += bits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Rng::new(0);
        for bits in 1..=8usize {
            let n = 97; // deliberately not a multiple of 8
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let packed = pack(&codes, bits);
            assert_eq!(packed.len(), packed_size(n, bits));
            assert_eq!(unpack(&packed, bits, n), codes);
        }
    }

    #[test]
    fn density_exact() {
        // 2-bit codes: exactly 4 per byte.
        let codes = vec![3u8; 256];
        assert_eq!(pack(&codes, 2).len(), 64);
        // 3-bit: 96 codes -> 36 bytes.
        let codes = vec![5u8; 96];
        assert_eq!(pack(&codes, 3).len(), 36);
    }

    #[test]
    fn crossing_byte_boundaries() {
        // 3-bit values crossing every byte boundary pattern.
        let codes: Vec<u8> = (0..16).map(|i| (i % 8) as u8).collect();
        let packed = pack(&codes, 3);
        assert_eq!(unpack(&packed, 3, 16), codes);
    }

    #[test]
    fn prop_unpack_into_offsets() {
        // Unpacking any sub-range at any code offset matches the slice of
        // the full unpack — the invariant the row-panel serving tiles rely on.
        crate::util::prop::quick(
            "unpack_into at arbitrary offsets",
            |rng| {
                let bits = 1 + rng.below(8);
                let n = 2 + rng.below(300);
                let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
                let off = rng.below(n);
                let len = 1 + rng.below(n - off);
                (bits, codes, off, len)
            },
            |(bits, codes, off, len)| {
                let packed = pack(codes, *bits);
                let mut got = vec![0u8; *len];
                unpack_into(&packed, *bits, *off, &mut got);
                if got == codes[*off..*off + *len] {
                    Ok(())
                } else {
                    Err(format!("mismatch at offset {off} len {len}"))
                }
            },
        );
    }

    #[test]
    fn prop_wide_roundtrip_all_widths() {
        // Wide codes round-trip at every width 1-16, at arbitrary offsets.
        crate::util::prop::quick(
            "pack_wide/unpack_wide_into roundtrip",
            |rng| {
                let bits = 1 + rng.below(16);
                let n = 2 + rng.below(300);
                let codes: Vec<u16> =
                    (0..n).map(|_| rng.below(1usize << bits) as u16).collect();
                let off = rng.below(n);
                let len = 1 + rng.below(n - off);
                (bits, codes, off, len)
            },
            |(bits, codes, off, len)| {
                let packed = pack_wide(codes, *bits);
                assert_eq!(packed.len(), packed_size(codes.len(), *bits));
                let mut got = vec![0u16; *len];
                unpack_wide_into(&packed, *bits, *off, &mut got);
                if got == codes[*off..*off + *len] {
                    Ok(())
                } else {
                    Err(format!("bits={bits} mismatch at offset {off} len {len}"))
                }
            },
        );
    }

    #[test]
    fn wide_stream_matches_narrow_for_low_bits() {
        // For bits <= 8 the wide packer emits byte-identical streams, so the
        // OACPACK1 format is unchanged by the u16-code widening.
        let mut rng = Rng::new(7);
        for bits in 1..=8usize {
            let n = 131;
            let narrow: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let wide: Vec<u16> = narrow.iter().map(|&c| c as u16).collect();
            assert_eq!(pack(&narrow, bits), pack_wide(&wide, bits), "bits={bits}");
        }
    }

    #[test]
    fn wide_16bit_exact() {
        let codes: Vec<u16> = vec![0, 1, 65535, 32768, 12345];
        let packed = pack_wide(&codes, 16);
        assert_eq!(packed.len(), 10);
        let mut got = vec![0u16; codes.len()];
        unpack_wide_into(&packed, 16, 0, &mut got);
        assert_eq!(got, codes);
    }

    #[test]
    fn prop_roundtrip() {
        crate::util::prop::quick(
            "pack/unpack roundtrip",
            |rng| {
                let bits = 1 + rng.below(8);
                let n = 1 + rng.below(200);
                let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
                (bits, codes)
            },
            |(bits, codes)| {
                let packed = pack(codes, *bits);
                let got = unpack(&packed, *bits, codes.len());
                if got == *codes {
                    Ok(())
                } else {
                    Err("mismatch".into())
                }
            },
        );
    }
}

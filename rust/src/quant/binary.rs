//! Binarization primitives for BiLLM-style 1-bit PTQ.
//!
//! BiLLM (Huang et al., ICML 2024) splits each weight row into:
//!   * **salient** columns (structurally selected by Hessian-weighted
//!     saliency) -> *residual binarization*: w ≈ α₁ sign(w) + α₂ sign(r)
//!     with r the residual after the first pass (2 effective passes);
//!   * **non-salient** weights -> *bell-split*: the concentrated bell of
//!     near-zero weights and the two tails are binarized as separate groups
//!     (each with its own optimal α = mean |w| over the group), because a
//!     single α fits a bimodal magnitude distribution poorly.

use crate::tensor::Mat;

/// Optimal 1-bit approximation of a set of values under l2:
/// b = sign(w), α = mean(|w|). Returns (alpha, approximation).
pub fn binarize(vals: &[f32]) -> (f32, Vec<f32>) {
    if vals.is_empty() {
        return (0.0, vec![]);
    }
    // oac-lint: allow(float-merge, "per-row serial mean |w|; row order is fixed by the caller")
    let alpha = vals.iter().map(|v| v.abs()).sum::<f32>() / vals.len() as f32;
    let approx = vals.iter().map(|v| alpha * v.signum()).collect();
    (alpha, approx)
}

/// Residual binarization (two passes): w ≈ α₁ b₁ + α₂ b₂.
pub fn residual_binarize(vals: &[f32]) -> (f32, f32, Vec<f32>) {
    let (a1, first) = binarize(vals);
    let residual: Vec<f32> = vals.iter().zip(&first).map(|(v, f)| v - f).collect();
    let (a2, second) = binarize(&residual);
    let approx = first.iter().zip(&second).map(|(f, s)| f + s).collect();
    (a1, a2, approx)
}

/// Split a magnitude distribution at `thresh`: indices with |w| < thresh
/// form the "bell", the rest the "tails". Each group is binarized with its
/// own α. Returns the combined approximation.
pub fn bell_split_binarize(vals: &[f32], thresh: f32) -> Vec<f32> {
    let mut bell = Vec::new();
    let mut tail = Vec::new();
    for (i, &v) in vals.iter().enumerate() {
        if v.abs() < thresh {
            bell.push((i, v));
        } else {
            tail.push((i, v));
        }
    }
    let (ab, _) = binarize(&bell.iter().map(|x| x.1).collect::<Vec<_>>());
    let (at, _) = binarize(&tail.iter().map(|x| x.1).collect::<Vec<_>>());
    let mut out = vec![0.0f32; vals.len()];
    for (i, v) in bell {
        out[i] = ab * v.signum();
    }
    for (i, v) in tail {
        out[i] = at * v.signum();
    }
    out
}

/// Search the bell-split threshold minimizing l2 error (BiLLM's "splitting
/// search"), over percentiles of |w|.
pub fn optimal_bell_split(vals: &[f32]) -> (f32, Vec<f32>) {
    let mut mags: Vec<f32> = vals.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut best = (f32::INFINITY, 0.0f32, Vec::new());
    for pct in [10, 20, 30, 40, 50, 60, 70, 80, 90] {
        let idx = (mags.len() * pct / 100).min(mags.len() - 1);
        let thresh = mags[idx];
        let approx = bell_split_binarize(vals, thresh);
        let err: f32 = vals.iter().zip(&approx).map(|(v, a)| (v - a).powi(2)).sum();
        if err < best.0 {
            best = (err, thresh, approx);
        }
    }
    (best.1, best.2)
}

/// Binarize an entire matrix row-wise with the bell split (non-salient path).
pub fn bell_binarize_mat(w: &Mat) -> Mat {
    let mut out = w.clone();
    for r in 0..w.rows {
        let (_, approx) = optimal_bell_split(w.row(r));
        out.row_mut(r).copy_from_slice(&approx);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn binarize_alpha_optimal() {
        // For fixed signs b, l2 error is minimized at alpha = mean|w|:
        // check small perturbations only increase error.
        let mut rng = Rng::new(0);
        let vals: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        let (alpha, _) = binarize(&vals);
        let err = |a: f32| -> f32 {
            vals.iter().map(|v| (v - a * v.signum()).powi(2)).sum()
        };
        assert!(err(alpha) <= err(alpha + 0.01) + 1e-6);
        assert!(err(alpha) <= err(alpha - 0.01) + 1e-6);
    }

    #[test]
    fn residual_reduces_error() {
        let mut rng = Rng::new(1);
        let vals: Vec<f32> = (0..128).map(|_| rng.normal_f32()).collect();
        let (_, one) = binarize(&vals);
        let (_, _, two) = residual_binarize(&vals);
        let e1: f32 = vals.iter().zip(&one).map(|(v, a)| (v - a).powi(2)).sum();
        let e2: f32 = vals.iter().zip(&two).map(|(v, a)| (v - a).powi(2)).sum();
        assert!(e2 < e1, "{e2} vs {e1}");
    }

    #[test]
    fn bell_split_beats_single_alpha_on_bimodal() {
        // Mixture: 80% tiny bell + 20% large tails — BiLLM's motivating shape.
        let mut rng = Rng::new(2);
        let mut vals = Vec::new();
        for i in 0..200 {
            if i % 5 == 0 {
                vals.push(rng.normal_f32() * 2.0 + 3.0 * if rng.uniform() < 0.5 { -1.0 } else { 1.0 });
            } else {
                vals.push(rng.normal_f32() * 0.05);
            }
        }
        let (_, single) = binarize(&vals);
        let (_, split) = optimal_bell_split(&vals);
        let e1: f32 = vals.iter().zip(&single).map(|(v, a)| (v - a).powi(2)).sum();
        let e2: f32 = vals.iter().zip(&split).map(|(v, a)| (v - a).powi(2)).sum();
        assert!(e2 < e1, "{e2} vs {e1}");
    }

    #[test]
    fn binarize_empty_and_constant() {
        assert_eq!(binarize(&[]).0, 0.0);
        let (a, approx) = binarize(&[0.5, 0.5]);
        assert!((a - 0.5).abs() < 1e-7);
        assert_eq!(approx, vec![0.5, 0.5]);
    }

    #[test]
    fn mat_binarization_two_levels_per_row_group() {
        let mut rng = Rng::new(3);
        let mut w = Mat::zeros(4, 64);
        rng.fill_normal(&mut w.data, 1.0);
        let b = bell_binarize_mat(&w);
        // Each row uses at most 4 distinct magnitudes (±α_bell, ±α_tail).
        for r in 0..4 {
            let mut mags: Vec<f32> = b.row(r).iter().map(|v| v.abs()).collect();
            mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
            mags.dedup_by(|a, b| (*a - *b).abs() < 1e-6);
            assert!(mags.len() <= 2, "row {r} has {} magnitudes", mags.len());
        }
    }
}

//! Group-wise asymmetric uniform quantization — the core primitive shared by
//! RTN, OPTQ, SpQR and the OAC variants. Mirrors `kernels/qdq.py` (the L1
//! Pallas kernel) exactly; `runtime::tests::qdq_artifact_matches_cpu_reference`
//! pins the two implementations together.

use crate::tensor::Mat;

/// Per-(row, group) affine quantization parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupParams {
    pub scale: f32,
    pub zero: f32,
}

/// Compute scale/zero for one group of values (asymmetric min-max).
pub fn group_params(vals: &[f32], bits: usize) -> GroupParams {
    let levels = ((1usize << bits) - 1) as f32;
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in vals {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !lo.is_finite() || !hi.is_finite() || hi <= lo {
        return GroupParams { scale: 0.0, zero: 0.0 }; // degenerate: passthrough
    }
    let scale = (hi - lo) / levels;
    let zero = (-lo / scale).round();
    GroupParams { scale, zero }
}

/// Compute scale/zero with a clipping ratio in (0, 1] (OmniQuant-lite's
/// learnable clipping: shrink the range before fitting the grid).
pub fn group_params_clipped(vals: &[f32], bits: usize, clip: f32) -> GroupParams {
    let levels = ((1usize << bits) - 1) as f32;
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in vals {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if hi <= lo {
        return GroupParams { scale: 0.0, zero: 0.0 };
    }
    let mid = 0.5 * (hi + lo);
    let lo = mid + (lo - mid) * clip;
    let hi = mid + (hi - mid) * clip;
    let scale = (hi - lo) / levels;
    if scale <= 0.0 {
        return GroupParams { scale: 0.0, zero: 0.0 };
    }
    let zero = (-lo / scale).round();
    GroupParams { scale, zero }
}

/// Quantize a single value to its integer level.
#[inline]
pub fn quantize(v: f32, p: GroupParams, bits: usize) -> f32 {
    if p.scale <= 0.0 {
        return 0.0;
    }
    let levels = ((1usize << bits) - 1) as f32;
    ((v / p.scale).round() + p.zero).clamp(0.0, levels)
}

/// Dequantize an integer level.
#[inline]
pub fn dequantize(q: f32, p: GroupParams) -> f32 {
    (q - p.zero) * p.scale
}

/// Quantize–dequantize a single value (degenerate params pass through).
#[inline]
pub fn qdq(v: f32, p: GroupParams, bits: usize) -> f32 {
    if p.scale <= 0.0 {
        return v;
    }
    dequantize(quantize(v, p, bits), p)
}

/// Whole-matrix group-wise quantize-dequantize (RTN). Groups along columns.
/// CPU twin of the L1 `qdq` Pallas kernel.
pub fn qdq_mat(w: &Mat, group_size: usize, bits: usize) -> Mat {
    assert_eq!(w.cols % group_size, 0, "cols {} % group {}", w.cols, group_size);
    let mut out = w.clone();
    for r in 0..w.rows {
        for g0 in (0..w.cols).step_by(group_size) {
            let row = &w.row(r)[g0..g0 + group_size];
            let p = group_params(row, bits);
            let dst = &mut out.row_mut(r)[g0..g0 + group_size];
            for (d, &v) in dst.iter_mut().zip(row.iter()) {
                *d = qdq(v, p, bits);
            }
        }
    }
    out
}

/// All group params of a matrix (row-major group order), for accounting and
/// the second-round scale/zero quantization.
pub fn all_group_params(w: &Mat, group_size: usize, bits: usize) -> Vec<GroupParams> {
    let mut out = Vec::with_capacity(w.rows * w.cols / group_size);
    for r in 0..w.rows {
        for g0 in (0..w.cols).step_by(group_size) {
            out.push(group_params(&w.row(r)[g0..g0 + group_size], bits));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn qdq_error_bounded_by_half_step() {
        let mut rng = Rng::new(0);
        for bits in [2usize, 3, 4, 8] {
            let vals: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
            let p = group_params(&vals, bits);
            for &v in &vals {
                let err = (qdq(v, p, bits) - v).abs();
                assert!(err <= p.scale * 0.5 + 1e-6, "bits={bits} err={err} scale={}", p.scale);
            }
        }
    }

    #[test]
    fn endpoints_representable() {
        let vals = [-1.0f32, -0.5, 0.3, 2.0];
        let p = group_params(&vals, 2);
        // Min and max of the group should round-trip near-exactly.
        assert!((qdq(-1.0, p, 2) - -1.0).abs() < 1e-6);
        assert!((qdq(2.0, p, 2) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn constant_group_passthrough() {
        let vals = [0.7f32; 16];
        let p = group_params(&vals, 2);
        assert_eq!(p.scale, 0.0);
        assert_eq!(qdq(0.7, p, 2), 0.7);
    }

    #[test]
    fn qdq_mat_matches_elementwise() {
        let mut rng = Rng::new(1);
        let mut w = Mat::zeros(8, 32);
        rng.fill_normal(&mut w.data, 0.5);
        let out = qdq_mat(&w, 16, 3);
        for r in 0..8 {
            for g0 in (0..32).step_by(16) {
                let p = group_params(&w.row(r)[g0..g0 + 16], 3);
                for c in g0..g0 + 16 {
                    assert_eq!(out.at(r, c), qdq(w.at(r, c), p, 3));
                }
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(2);
        let mut w = Mat::zeros(16, 64);
        rng.fill_normal(&mut w.data, 1.0);
        let e2 = qdq_mat(&w, 16, 2).mse(&w);
        let e3 = qdq_mat(&w, 16, 3).mse(&w);
        let e4 = qdq_mat(&w, 16, 4).mse(&w);
        assert!(e2 > e3 && e3 > e4, "{e2} {e3} {e4}");
    }

    #[test]
    fn smaller_groups_less_error() {
        let mut rng = Rng::new(3);
        let mut w = Mat::zeros(16, 64);
        rng.fill_normal(&mut w.data, 1.0);
        let e_small = qdq_mat(&w, 8, 2).mse(&w);
        let e_large = qdq_mat(&w, 64, 2).mse(&w);
        assert!(e_small < e_large, "{e_small} vs {e_large}");
    }

    #[test]
    fn clip_grid_search_never_loses() {
        // The OmniQuant-lite invariant: searching clip ∈ grid (incl. 1.0)
        // is at least as good as plain min-max, and strictly better on
        // heavy-tailed groups for some seeds.
        let mut rng = Rng::new(4);
        let mut strictly_better = 0;
        for trial in 0..20 {
            let vals: Vec<f32> = (0..32)
                .map(|_| {
                    let z = rng.normal_f32();
                    z * z * z * 0.3 // heavy-tailed
                })
                .collect();
            let err = |p: GroupParams| -> f32 {
                vals.iter().map(|&v| (qdq(v, p, 2) - v).powi(2)).sum()
            };
            let e_full = err(group_params(&vals, 2));
            let e_best = [1.0f32, 0.9, 0.8, 0.7, 0.6, 0.5]
                .iter()
                .map(|&c| err(group_params_clipped(&vals, 2, c)))
                .fold(f32::INFINITY, f32::min);
            assert!(e_best <= e_full + 1e-6, "trial {trial}");
            if e_best < e_full * 0.99 {
                strictly_better += 1;
            }
        }
        assert!(strictly_better > 0, "clipping never helped on heavy tails");
    }

    #[test]
    fn prop_qdq_idempotent() {
        crate::util::prop::quick(
            "qdq is idempotent",
            |rng| {
                let vals: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
                vals
            },
            |vals| {
                let p = group_params(vals, 3);
                for &v in vals {
                    let once = qdq(v, p, 3);
                    let twice = qdq(once, p, 3);
                    if (once - twice).abs() > 1e-5 {
                        return Err(format!("{v}: {once} -> {twice}"));
                    }
                }
                Ok(())
            },
        );
    }
}

//! Second-round quantization of the first-round scales and zeros
//! (SpQR's "double quantization", paper Fig. 3 step 7).
//!
//! First-round group params (one scale+zero per group of 16 weights) would
//! cost 2×16 bits/group in fp16 = 2 extra bits/weight. SpQR instead
//! quantizes the per-group scales and zeros themselves to `stat_bits` (3 in
//! the paper) with one fp32 scale pair per *super-group* of `supergroup`
//! consecutive groups, making tiny groups affordable. This module performs
//! that second round and reports the exact bit cost.

use super::uniform::{group_params, qdq, GroupParams};

#[derive(Debug, Clone)]
pub struct ScaleQuantResult {
    /// Quantize-dequantized group params (what the decoder will see).
    pub params: Vec<GroupParams>,
    /// Total parameter storage in bits (quantized stats + supergroup fp32).
    pub param_bits: usize,
}

/// Quantize the group scales/zeros to `stat_bits` within super-groups of
/// `supergroup` groups. Returns decoder-visible params + exact bit cost.
pub fn quantize_group_params(
    params: &[GroupParams],
    stat_bits: usize,
    supergroup: usize,
) -> ScaleQuantResult {
    let mut out = Vec::with_capacity(params.len());
    let mut param_bits = 0usize;
    for chunk in params.chunks(supergroup) {
        let scales: Vec<f32> = chunk.iter().map(|p| p.scale).collect();
        let zeros: Vec<f32> = chunk.iter().map(|p| p.zero).collect();
        let ps = group_params(&scales, stat_bits);
        let pz = group_params(&zeros, stat_bits);
        // Cost: stat_bits per scale + per zero, plus two fp32 pairs per
        // super-group for the second-level params.
        param_bits += chunk.len() * stat_bits * 2 + 2 * 2 * 32;
        for p in chunk {
            out.push(GroupParams {
                scale: qdq(p.scale, ps, stat_bits).max(0.0),
                zero: qdq(p.zero, pz, stat_bits).round(),
            });
        }
    }
    ScaleQuantResult { params: out, param_bits }
}

/// Bit cost of storing params directly in fp16 (the no-second-round option,
/// for the accounting ablation).
pub fn fp16_param_bits(n_groups: usize) -> usize {
    n_groups * 2 * 16
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_params(n: usize, seed: u64) -> Vec<GroupParams> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| GroupParams {
                scale: 0.01 + rng.uniform_f32() * 0.1,
                zero: rng.below(4) as f32,
            })
            .collect()
    }

    #[test]
    fn cheaper_than_fp16() {
        let params = random_params(256, 0);
        let r = quantize_group_params(&params, 3, 16);
        assert!(r.param_bits < fp16_param_bits(256), "{} vs {}", r.param_bits, fp16_param_bits(256));
    }

    #[test]
    fn params_stay_close() {
        let params = random_params(64, 1);
        let r = quantize_group_params(&params, 3, 16);
        for (orig, got) in params.iter().zip(&r.params) {
            // 3-bit grid over the supergroup's scale range: within a step.
            let step = 0.1 / 7.0;
            assert!((orig.scale - got.scale).abs() <= step + 1e-6);
        }
    }

    #[test]
    fn zeros_remain_integral() {
        let params = random_params(64, 2);
        let r = quantize_group_params(&params, 3, 8);
        for p in &r.params {
            assert_eq!(p.zero, p.zero.round());
        }
    }

    #[test]
    fn scales_stay_nonnegative() {
        let params = random_params(32, 3);
        let r = quantize_group_params(&params, 2, 8);
        for p in &r.params {
            assert!(p.scale >= 0.0);
        }
    }

    #[test]
    fn exact_bit_accounting() {
        let params = random_params(32, 4);
        let r = quantize_group_params(&params, 3, 16);
        // 2 supergroups: 32 * 3 * 2 + 2 * 128 = 192 + 256
        assert_eq!(r.param_bits, 32 * 3 * 2 + 2 * 2 * 2 * 32);
    }
}

//! Offline stand-in for the `log` facade crate (crates.io is unavailable in
//! the build environment). Implements the subset this workspace uses:
//! [`Level`], [`LevelFilter`], [`Metadata`], [`Record`], the [`Log`] trait,
//! [`set_logger`] / [`set_max_level`], and the level macros.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a single log record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// Global maximum-verbosity filter.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record (level only in this stand-in).
#[derive(Clone, Copy, Debug)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log record: metadata plus preformatted arguments.
pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink. Implementations decide filtering and output.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        false
    }
    fn log(&self, _record: &Record) {}
    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // LevelFilter::Off

/// The error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger, or a no-op sink before installation.
pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

/// Set the global maximum level checked by the macros.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::SeqCst);
}

/// The current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::SeqCst) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro implementation detail: build a record and dispatch it.
pub fn __private_log(level: Level, args: fmt::Arguments<'_>) {
    let record = Record { metadata: Metadata { level }, args };
    logger().log(&record);
}

/// Log at an explicit level.
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_log(lvl, ::std::format_args!($($arg)+));
        }
    }};
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_matches_log_crate() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Info));
        assert!(!(Level::Error <= LevelFilter::Off));
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }

    #[test]
    fn display_pads() {
        assert_eq!(format!("{:<5}", Level::Warn), "WARN ");
    }
}

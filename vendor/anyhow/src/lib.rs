//! Offline stand-in for the `anyhow` crate (crates.io is unavailable in the
//! build environment). Implements the API subset this workspace uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error values carry a flat context
//! chain (outermost first); `{:#}` formatting prints the full chain.

use std::fmt;

/// A dynamically-typed error with a chain of context messages.
///
/// Unlike the real `anyhow::Error` this stores messages eagerly as strings —
/// downcasting is not supported, which none of this workspace needs.
pub struct Error {
    /// Context chain, outermost message first.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn push_context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Wrap with an additional layer of context (outermost).
    pub fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Error {
        self.push_context(context)
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts into `Error`, capturing its source chain. `Error`
// itself deliberately does NOT implement `std::error::Error` so this blanket
// impl cannot overlap the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().push_context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: {}",
                ::std::stringify!($cond)
            ));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chain_and_alternate_display() {
        let e: Error = Err::<(), _>(io_err()).context("opening config").unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing");
    }

    #[test]
    fn option_context() {
        let e = None::<u8>.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");
    }

    #[test]
    fn macros() {
        fn f(ok: bool) -> Result<u8> {
            ensure!(ok, "flag was {ok}");
            if !ok {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag was false");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }
}

//! Offline stub of the `xla` PJRT bindings used by the runtime layer.
//!
//! The real crate links libxla/PJRT and executes AOT HLO artifacts; that
//! toolchain is not present in this build environment, so this stub keeps
//! the *host-side* surface functional (Literal construction, reshape,
//! typed extraction) while every device-facing entry point
//! ([`PjRtClient::cpu`], compilation, buffer upload, execution) returns a
//! clean error. The coordinator, tests and benches all gate on
//! `Runtime::new()` / artifact presence, so the rest of the crate works —
//! including the artifact-free synthetic pipeline — without XLA installed.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    fn unavailable() -> Error {
        Error::new("xla runtime unavailable: built with the offline stub (vendor/xla)")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// ------------------------------------------------------------------ literals

/// Typed storage behind a [`Literal`].
#[derive(Clone, Debug)]
enum Store {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold in this stub.
pub trait NativeType: Copy {
    fn store(vals: &[Self]) -> Store;
    fn read(store: &Store) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn store(vals: &[Self]) -> Store {
        Store::F32(vals.to_vec())
    }
    fn read(store: &Store) -> Result<Vec<Self>> {
        match store {
            Store::F32(v) => Ok(v.clone()),
            _ => Err(Error::new("literal element type mismatch: expected f32")),
        }
    }
}

impl NativeType for i32 {
    fn store(vals: &[Self]) -> Store {
        Store::I32(vals.to_vec())
    }
    fn read(store: &Store) -> Result<Vec<Self>> {
        match store {
            Store::I32(v) => Ok(v.clone()),
            _ => Err(Error::new("literal element type mismatch: expected i32")),
        }
    }
}

/// Array shape: dimension sizes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Shape of a literal: a dense array or a tuple.
#[derive(Clone, Debug)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// A host-resident typed array (fully functional in the stub).
#[derive(Clone, Debug)]
pub struct Literal {
    store: Store,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(vals: &[T]) -> Literal {
        Literal { store: T::store(vals), dims: vec![vals.len() as i64] }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(val: T) -> Literal {
        Literal { store: T::store(&[val]), dims: vec![] }
    }

    fn element_count(&self) -> usize {
        match &self.store {
            Store::F32(v) => v.len(),
            Store::I32(v) => v.len(),
            Store::Tuple(_) => 0,
        }
    }

    /// Same data, new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if matches!(self.store, Store::Tuple(_)) {
            return Err(Error::new("cannot reshape a tuple literal"));
        }
        if n as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape element count mismatch: {} vs {:?}",
                self.element_count(),
                dims
            )));
        }
        Ok(Literal { store: self.store.clone(), dims: dims.to_vec() })
    }

    /// Extract all elements as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read(&self.store)
    }

    /// The first element (scalar extraction).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error::new("empty literal"))
    }

    /// Storage footprint in bytes (elements are 4 bytes wide here).
    pub fn size_bytes(&self) -> usize {
        match &self.store {
            Store::F32(v) => v.len() * 4,
            Store::I32(v) => v.len() * 4,
            Store::Tuple(ls) => ls.iter().map(|l| l.size_bytes()).sum(),
        }
    }

    pub fn shape(&self) -> Result<Shape> {
        match &self.store {
            Store::Tuple(ls) => Ok(Shape::Tuple(
                ls.iter().map(|l| l.shape()).collect::<Result<_>>()?,
            )),
            _ => Ok(Shape::Array(ArrayShape { dims: self.dims.clone() })),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.store {
            Store::Tuple(_) => Err(Error::new("array_shape on a tuple literal")),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }

    /// Split a tuple literal into its elements.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.store, Store::F32(Vec::new())) {
            Store::Tuple(ls) => Ok(ls),
            other => {
                self.store = other;
                Err(Error::new("decompose_tuple on a non-tuple literal"))
            }
        }
    }
}

// ------------------------------------------------------------- device stubs

/// Stub device buffer: can never be constructed (all upload paths fail), so
/// every method is statically unreachable but must typecheck.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }

    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// Stub PJRT client: construction fails so callers degrade gracefully.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable())
    }
}

/// Stub HLO module proto: text parsing requires the real toolchain.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::new(
            "xla stub cannot parse HLO artifacts (offline build without PJRT)",
        ))
    }
}

/// Stub computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(Literal::scalar(7i32).get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn typed_mismatch_rejected() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.size_bytes(), 8);
    }

    #[test]
    fn device_paths_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
    }
}

//! End-to-end driver (DESIGN.md §5, recorded in EXPERIMENTS.md):
//!
//! 1. **Train** a transformer LM from scratch on the synthetic corpus via
//!    the AOT `train_step` artifact (fwd+bwd+Adam fused at build time; Rust
//!    drives the loop). Logs the loss curve.
//! 2. **Quantize** the trained checkpoint to 2 bits with RTN, SpQR and OAC
//!    (paper Table 1 mini).
//! 3. **Evaluate** perplexity (C4*/WikiText2* splits) + reasoning-task
//!    accuracy for each, proving all three layers compose.
//!
//! Run: cargo run --release --example e2e_train_quant_eval [-- --config small]

use anyhow::Result;
use oac::calib::{Backend, Method};
use oac::experiments::{baseline_row, method_row, Workbench, WorkbenchConfig, ROW_HEADERS};
use oac::report::Table;
use oac::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&[]);
    let config = args.str_or("config", "small");
    let mut wcfg = WorkbenchConfig::new(&config);
    wcfg.eval.with_far_split = true;

    println!("== e2e: train -> quantize -> eval ({config}) ==");
    let t0 = std::time::Instant::now();
    let wb = Workbench::new(wcfg)?; // trains (or loads) the checkpoint
    println!("[1/3] checkpoint ready in {:.1}s", t0.elapsed().as_secs_f64());

    let base = wb.eval_baseline()?;
    println!(
        "[2/3] baseline: ppl {:.2} (C4*) {:.2} (WikiText2*), tasks {:.1}%",
        base.ppl_in_domain,
        base.ppl_shifted,
        base.task_avg()
    );

    let mut table = Table::new(
        format!("2-bit PTQ on `{config}` (paper Table 1 mini)"),
        &ROW_HEADERS,
    );
    table.row(baseline_row(&base));
    for method in [
        Method::baseline(Backend::RTN),
        Method::baseline(Backend::SPQR),
        Method::oac(Backend::SPQR),
    ] {
        let t = std::time::Instant::now();
        let (qr, er) = wb.run(&wb.pipeline(method, 2))?;
        println!(
            "[3/3] {:<6} quantized+evaluated in {:.1}s (phase1 {:.1}s, phase2 {:.1}s)",
            qr.method,
            t.elapsed().as_secs_f64(),
            qr.phase1_secs,
            qr.phase2_secs
        );
        table.row(method_row(&qr.method, qr.avg_bits, &er));
    }
    table.print();
    println!("total e2e wall clock: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

//! Quickstart: the public API on a single linear layer, no training needed.
//!
//! 1. Load the AOT artifact metadata (`make artifacts` first).
//! 2. Build an output-adaptive Hessian from gradient matrices produced by
//!    the `model_grads` artifact, contracted by the L1 Pallas kernel.
//! 3. Quantize one layer to 2 bits with every backend and compare the
//!    quadratic calibration error.
//!
//! Run: cargo run --release --example quickstart

use anyhow::Result;
use oac::calib::{Backend, CalibConfig, LayerCtx, Method};
use oac::coordinator::{Coordinator, PipelineConfig};
use oac::data::{Flavor, Splits};
use oac::experiments::artifacts_root;
use oac::model::{ModelMeta, WeightStore};
use oac::report::Table;
use oac::runtime::Runtime;

fn main() -> Result<()> {
    oac::util::logging::init();
    let rt = Runtime::new()?;
    let meta = ModelMeta::load(artifacts_root(), "tiny")?;
    println!(
        "model `tiny`: {} params, {} quantizable linear layers",
        meta.total_params(),
        meta.linear_layers.len()
    );

    // Random-init weights (quantization mechanics work the same; training
    // matters for the *evaluation*, which the e2e example covers).
    let ws = WeightStore::init_random(&meta, 0);
    let splits = Splits::new(meta.vocab, Flavor::C4Analog, 0);
    let calib = splits.calibration(4, meta.seq);

    // Phase 1 (per paper Algorithm 1) for block 0, both Hessian kinds.
    let coord = Coordinator::new(&rt, &meta)?;
    let oac_cfg = PipelineConfig::new(Method::oac(Backend::SPQR), 2);
    let agn_cfg = PipelineConfig::new(Method::baseline(Backend::SPQR), 2);
    let h_oac = coord.block_hessians(&ws, 0, &calib, &oac_cfg)?;
    let h_agn = coord.block_hessians(&ws, 0, &calib, &agn_cfg)?;

    let layer = &meta.linear_layers[0]; // blocks.0.q
    let w = ws.get_mat(&layer.name);
    println!("\nquantizing {} ({}x{}) to 2 bits\n", layer.name, w.rows, w.cols);

    let cfg = CalibConfig::for_bits(2);
    let mut table = Table::new(
        "Per-backend quadratic calibration error (lower is better)",
        &["Backend", "Hessian", "tr(dW H dW^T)", "Avg Bits"],
    );
    for (kind, hmap) in [("agnostic", &h_agn), ("output-adaptive", &h_oac)] {
        let damped = hmap[&layer.name].regularized(cfg.alpha, cfg.reduction);
        let prepared = oac::hessian::prepare(damped)?;
        for backend in [Backend::RTN, Backend::OPTQ, Backend::SPQR, Backend::QUIP] {
            // The one dispatch point every backend shares: the trait object.
            let q = backend.quantize(&LayerCtx {
                name: &layer.name,
                w: &w,
                hessian: &prepared,
                cfg: &cfg,
            });
            table.row(vec![
                backend.name().to_string(),
                kind.to_string(),
                format!("{:.4e}", q.calib_error),
                format!("{:.2}", q.budget.avg_bits()),
            ]);
        }
    }
    table.print();
    println!("note: errors across Hessian kinds are not directly comparable —");
    println!("the metric itself changes; the e2e example compares end metrics.");
    Ok(())
}

//! Backend ablation (paper Table 14 / Appendix I): plug the output-adaptive
//! Hessian into each Hessian-based calibration backend and show it improves
//! every one of them — the paper's claim that OAC is a *Hessian* upgrade,
//! orthogonal to the update rule.
//!
//! Run: cargo run --release --example backend_ablation [-- --config tiny]

use anyhow::Result;
use oac::calib::{Backend, Method};
use oac::experiments::{method_row, Workbench, WorkbenchConfig, ROW_HEADERS};
use oac::report::Table;
use oac::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&[]);
    let config = args.str_or("config", "tiny");
    let wb = Workbench::new(WorkbenchConfig::new(&config))?;

    let mut table = Table::new(
        format!("OAC x backend ablation on `{config}` (paper Table 14 analog)"),
        &ROW_HEADERS,
    );
    for backend in [Backend::OPTQ, Backend::QUIP, Backend::SPQR] {
        for method in [Method::baseline(backend), Method::oac(backend)] {
            let (qr, er) = wb.run(&wb.pipeline(method, 2))?;
            table.row(method_row(&qr.method, qr.avg_bits, &er));
        }
    }
    // Binary pair.
    for method in [Method::baseline(Backend::BILLM), Method::oac(Backend::BILLM)] {
        let (qr, er) = wb.run(&wb.pipeline(method, 1))?;
        table.row(method_row(&qr.method, qr.avg_bits, &er));
    }
    table.print();
    Ok(())
}

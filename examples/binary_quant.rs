//! Binary (1-bit) PTQ demo (paper Table 2): BiLLM vs OAC_BiLLM, plus what
//! happens if you naively binarize (RTN at 1 bit) — the paper's motivation
//! for structural selection + residual binarization.
//!
//! Run: cargo run --release --example binary_quant [-- --config tiny]

use anyhow::Result;
use oac::calib::{Backend, Method};
use oac::experiments::{baseline_row, method_row, Workbench, WorkbenchConfig, ROW_HEADERS};
use oac::report::Table;
use oac::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&[]);
    let config = args.str_or("config", "tiny");
    let wb = Workbench::new(WorkbenchConfig::new(&config))?;

    let mut table = Table::new(
        format!("Binary PTQ on `{config}` (paper Table 2 analog)"),
        &ROW_HEADERS,
    );
    table.row(baseline_row(&wb.eval_baseline()?));
    for method in [
        Method::baseline(Backend::RTN),
        Method::baseline(Backend::BILLM),
        Method::oac(Backend::BILLM),
    ] {
        let (qr, er) = wb.run(&wb.pipeline(method, 1))?;
        table.row(method_row(&qr.method, qr.avg_bits, &er));
    }
    table.print();
    println!("expected shape: RTN collapses; OAC_BiLLM < BiLLM on perplexity.");
    Ok(())
}

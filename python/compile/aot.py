"""AOT driver: lower every L2/L1 computation to HLO *text* artifacts.

Python runs ONCE, at build time (`make artifacts`); the Rust coordinator
loads the emitted `.hlo.txt` files through the PJRT CPU client and never
touches python again.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly. Lowered with return_tuple=True so the
rust side always unpacks a tuple. (See /opt/xla-example/gen_hlo.py.)

Emitted layout:

  artifacts/<cfg>/{model_fwd,model_loss,model_grads,layer_inputs,train_step}.hlo.txt
  artifacts/kernels/hessian_accum_<m>x<n>.hlo.txt
  artifacts/kernels/qdq_<r>x<c>_g<g>b<b>.hlo.txt
  artifacts/meta.json   (ordered weight names/shapes — the python<->rust ABI)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.hessian_accum import hessian_accum
from .kernels.qdq import qdq

# Group size / bit widths for the pre-lowered qdq kernel artifacts (perf +
# RTN-on-artifact paths; rust has its own CPU implementation for the rest).
QDQ_GROUP = 16
QDQ_BITS = (2, 3, 4)


def to_hlo_text(lowered, return_tuple=True):
    """return_tuple=False only for single-output kernels: the raw (untupled)
    output buffer can then be fed straight back as a PJRT input, which lets
    the rust coordinator chain Hessian accumulation on-device without a
    host round-trip per calibration sample (see runtime::run_b_raw)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def _f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _write(path, text):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")


def lower_config(cfg, out_dir):
    """Lower the five model artifacts for one ModelConfig."""
    wspecs = M.weight_spec(cfg)
    w_in = [_f32(s) for _, s in wspecs]
    tok = _i32((cfg.seq,))
    cdir = os.path.join(out_dir, cfg.name)
    arts = {}

    def emit(name, fn, *args):
        lowered = jax.jit(fn).lower(*args)
        _write(os.path.join(cdir, f"{name}.hlo.txt"), to_hlo_text(lowered))
        arts[name] = f"{cfg.name}/{name}.hlo.txt"

    emit("model_fwd",
         lambda *a: (M.forward(cfg, a[:-1], a[-1]),), *w_in, tok)
    emit("model_loss",
         lambda *a: (M.loss_sum(cfg, a[:-1], a[-1]),), *w_in, tok)
    emit("model_grads",
         lambda *a: M.linear_grads(cfg, a[:-1], a[-1]), *w_in, tok)
    emit("layer_inputs",
         lambda *a: M.layer_inputs(cfg, a[:-1], a[-1]), *w_in, tok)

    tok_hb = _i32((M.CALIB_BATCH, cfg.seq))
    emit("hessians_oac",
         lambda *a: M.batch_hessian_oac(cfg, a[:-1], a[-1]), *w_in, tok_hb)
    emit("hessians_agnostic",
         lambda *a: M.batch_hessian_agnostic(cfg, a[:-1], a[-1]), *w_in, tok_hb)

    nw = len(w_in)
    tok_b = _i32((cfg.train_batch, cfg.seq))

    def ts(*a):
        ws, ms, vs = a[:nw], a[nw:2 * nw], a[2 * nw:3 * nw]
        step, lr, toks = a[3 * nw], a[3 * nw + 1], a[3 * nw + 2]
        return M.train_step(cfg, ws, ms, vs, step, lr, toks)

    emit("train_step", ts, *(w_in * 3), _f32(()), _f32(()), tok_b)
    return arts


def kernel_shapes(cfgs):
    """Hessian-accum shapes needed at runtime, deduped across configs.

    OAC Hessians contract gradient matrices [d_row, d_col]; the agnostic
    baselines contract activations [seq, d_col]."""
    shapes = set()
    for cfg in cfgs:
        d, f, s = cfg.d_model, cfg.d_ff, cfg.seq
        shapes |= {(d, d), (f, d), (d, f), (s, d), (s, f)}
    return sorted(shapes)


def lower_kernels(cfgs, out_dir):
    kdir = os.path.join(out_dir, "kernels")
    hes = []
    for (m, n) in kernel_shapes(cfgs):
        name = f"hessian_accum_{m}x{n}"
        lowered = jax.jit(
            lambda g, h: hessian_accum(g, h)).lower(_f32((m, n)), _f32((n, n)))
        _write(os.path.join(kdir, f"{name}.hlo.txt"),
               to_hlo_text(lowered, return_tuple=False))
        hes.append({"m": m, "n": n, "path": f"kernels/{name}.hlo.txt"})

    qd = []
    for cfg in cfgs:
        d = cfg.d_model
        for bits in QDQ_BITS:
            name = f"qdq_{d}x{d}_g{QDQ_GROUP}b{bits}"
            if any(e["path"].endswith(f"{name}.hlo.txt") for e in qd):
                continue
            lowered = jax.jit(
                lambda w, b=bits: (qdq(w, group_size=QDQ_GROUP, bits=b),)
            ).lower(_f32((d, d)))
            _write(os.path.join(kdir, f"{name}.hlo.txt"), to_hlo_text(lowered))
            qd.append({"rows": d, "cols": d, "group": QDQ_GROUP,
                       "bits": bits, "path": f"kernels/{name}.hlo.txt"})
    return {"hessian_accum": hes, "qdq": qd}


def config_meta(cfg, arts):
    return {
        "d_model": cfg.d_model, "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "vocab": cfg.vocab,
        "seq": cfg.seq, "train_batch": cfg.train_batch,
        "calib_batch": M.CALIB_BATCH,
        "weights": [{"name": n, "shape": list(s)} for n, s in M.weight_spec(cfg)],
        "linear_layers": [
            {"name": n, "shape": list(s), "input": inp, "block": b}
            for n, s, inp, b in M.linear_layer_spec(cfg)
        ],
        "layer_inputs_order": [
            {"name": n, "shape": list(s)} for n, s in M.layer_input_spec(cfg)
        ],
        "artifacts": arts,
        "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny small",
                    help="space-separated subset of: " + " ".join(M.CONFIGS))
    args = ap.parse_args()

    names = args.configs.split()
    cfgs = [M.get_config(n) for n in names]
    meta = {"configs": {}, "kernels": {}}
    for cfg in cfgs:
        print(f"lowering config {cfg.name} ...")
        arts = lower_config(cfg, args.out_dir)
        meta["configs"][cfg.name] = config_meta(cfg, arts)
    print("lowering kernels ...")
    meta["kernels"] = lower_kernels(cfgs, args.out_dir)

    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()

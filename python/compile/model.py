"""L2: decoder-only transformer LM in functional JAX.

This is the model substrate the OAC pipeline quantizes. The paper evaluates
on OPT/LLaMa checkpoints; those are unavailable here, so the repo trains its
own size ladder of the same architecture family (RMSNorm, causal MHA, SiLU
MLP — the LLaMa block shape minus the gate matrix) and quantizes that. See
DESIGN.md §2.

Everything is a pure function over an ordered, flat tuple of weight arrays so
the AOT artifacts have a stable positional input signature that the Rust
runtime can feed (python/compile/aot.py writes the ordering to meta.json).

Per transformer block, the *quantizable* linear layers are (paper notation
W in R^{d_row x d_col}, y = W x):

  q, k, v, o : [d_model, d_model]
  up         : [d_ff,    d_model]
  down       : [d_model, d_ff]

Embeddings, norms and the LM head are kept in FP16/FP32 by all the paper's
methods and are likewise not quantized here.
"""

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

# Hessian accumulation kernel is exposed through the model module so the AOT
# driver lowers exactly the code path the tests verified.
from .kernels.hessian_accum import hessian_accum  # noqa: F401

CONFIGS = {
    # name: (d_model, n_layers, n_heads, d_ff, vocab, seq, train_batch)
    "tiny": dict(d_model=128, n_layers=2, n_heads=4, d_ff=512, vocab=256,
                 seq=64, train_batch=8),
    "small": dict(d_model=256, n_layers=4, n_heads=8, d_ff=1024, vocab=512,
                  seq=128, train_batch=8),
    "base": dict(d_model=512, n_layers=8, n_heads=8, d_ff=2048, vocab=1024,
                 seq=128, train_batch=8),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int
    seq: int
    train_batch: int

    @property
    def d_head(self):
        return self.d_model // self.n_heads


def get_config(name):
    return ModelConfig(name=name, **CONFIGS[name])


# --------------------------------------------------------------------------
# Weight layout (ordering is the ABI between python and rust)
# --------------------------------------------------------------------------

def weight_spec(cfg):
    """Ordered list of (name, shape) for every trainable array."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    spec = [("embed", (v, d)), ("pos_embed", (cfg.seq, d))]
    for i in range(cfg.n_layers):
        p = f"blocks.{i}."
        spec += [
            (p + "attn_norm", (d,)),
            (p + "q", (d, d)),
            (p + "k", (d, d)),
            (p + "v", (d, d)),
            (p + "o", (d, d)),
            (p + "mlp_norm", (d,)),
            (p + "up", (f, d)),
            (p + "down", (d, f)),
        ]
    spec += [("final_norm", (d,)), ("lm_head", (v, d))]
    return spec


def linear_layer_spec(cfg):
    """Ordered list of quantizable linear layers with their Hessian metadata.

    Each entry: (name, shape, input_capture_name, block_index). The
    ``input_capture_name`` keys into the layer_inputs artifact output (see
    ``layer_inputs``), giving the activation matrix whose X^T X is the
    output-agnostic Hessian for that layer.
    """
    d, f = cfg.d_model, cfg.d_ff
    out = []
    for i in range(cfg.n_layers):
        p = f"blocks.{i}."
        out += [
            (p + "q", (d, d), p + "x_attn", i),
            (p + "k", (d, d), p + "x_attn", i),
            (p + "v", (d, d), p + "x_attn", i),
            (p + "o", (d, d), p + "x_o", i),
            (p + "up", (f, d), p + "x_up", i),
            (p + "down", (d, f), p + "x_down", i),
        ]
    return out


def layer_input_spec(cfg):
    """Ordered list of (capture_name, shape) returned by ``layer_inputs``."""
    d, f, s = cfg.d_model, cfg.d_ff, cfg.seq
    out = []
    for i in range(cfg.n_layers):
        p = f"blocks.{i}."
        out += [
            (p + "x_attn", (s, d)),
            (p + "x_o", (s, d)),
            (p + "x_up", (s, d)),
            (p + "x_down", (s, f)),
        ]
    return out


def unflatten(cfg, flat):
    spec = weight_spec(cfg)
    assert len(flat) == len(spec), (len(flat), len(spec))
    return dict(zip([n for n, _ in spec], flat))


def init_weights(cfg, key):
    """Scaled-normal init (matches rust/src/model/weights.rs)."""
    ws = []
    for name, shape in weight_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            ws.append(jnp.ones(shape, jnp.float32))
        elif len(shape) == 2:
            std = 1.0 / math.sqrt(shape[1])
            ws.append(jax.random.normal(sub, shape, jnp.float32) * std)
        else:
            ws.append(jax.random.normal(sub, shape, jnp.float32) * 0.02)
    return ws


# --------------------------------------------------------------------------
# Forward / loss / grads
# --------------------------------------------------------------------------

def _rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def _attention(cfg, x, wq, wk, wv, wo):
    """Causal multi-head attention. Returns (out, context) where context is
    the pre-o-projection activation (the input of linear layer `o`)."""
    s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    q = (x @ wq.T).reshape(s, h, dh)
    k = (x @ wk.T).reshape(s, h, dh)
    v = (x @ wv.T).reshape(s, h, dh)
    scores = jnp.einsum("qhd,khd->hqk", q, k) / math.sqrt(dh)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hqk,khd->qhd", probs, v).reshape(s, d)
    return ctx @ wo.T, ctx


def _block(cfg, w, i, hdn, captures=None):
    p = f"blocks.{i}."
    x_attn = _rms_norm(hdn, w[p + "attn_norm"])
    attn_out, ctx = _attention(
        cfg, x_attn, w[p + "q"], w[p + "k"], w[p + "v"], w[p + "o"])
    hdn = hdn + attn_out
    x_up = _rms_norm(hdn, w[p + "mlp_norm"])
    act = jax.nn.silu(x_up @ w[p + "up"].T)
    hdn = hdn + act @ w[p + "down"].T
    if captures is not None:
        captures[p + "x_attn"] = x_attn
        captures[p + "x_o"] = ctx
        captures[p + "x_up"] = x_up
        captures[p + "x_down"] = act
    return hdn


def forward(cfg, weights_flat, tokens, captures=None):
    """tokens [seq] int32 -> logits [seq, vocab]."""
    w = unflatten(cfg, weights_flat)
    hdn = w["embed"][tokens] + w["pos_embed"]
    for i in range(cfg.n_layers):
        hdn = _block(cfg, w, i, hdn, captures)
    hdn = _rms_norm(hdn, w["final_norm"])
    return hdn @ w["lm_head"].T


def loss_sum(cfg, weights_flat, tokens):
    """Sum of next-token CE over the sequence (for exact perplexity)."""
    logits = forward(cfg, weights_flat, tokens)
    logp = jax.nn.log_softmax(logits[:-1].astype(jnp.float32), axis=-1)
    tgt = tokens[1:]
    nll = -jnp.take_along_axis(logp, tgt[:, None], axis=-1)[:, 0]
    return jnp.sum(nll)


def loss_mean(cfg, weights_flat, tokens):
    return loss_sum(cfg, weights_flat, tokens) / (cfg.seq - 1)


def linear_grads(cfg, weights_flat, tokens):
    """Per-sample gradient matrices G[i] of the mean CE loss w.r.t. every
    quantizable linear weight (paper Algorithm 1 lines 4-9), in
    linear_layer_spec order."""
    names = [n for n, _ in weight_spec(cfg)]
    lin_names = [e[0] for e in linear_layer_spec(cfg)]
    lin_idx = [names.index(n) for n in lin_names]

    def loss_of_lin(lin_ws):
        full = list(weights_flat)
        for j, idx in enumerate(lin_idx):
            full[idx] = lin_ws[j]
        return loss_mean(cfg, tuple(full), tokens)

    grads = jax.grad(loss_of_lin)(tuple(weights_flat[i] for i in lin_idx))
    return tuple(grads)


def layer_inputs(cfg, weights_flat, tokens):
    """Activation matrices entering each linear layer (for the
    output-agnostic baselines' X^T X Hessian), in layer_input_spec order.

    Also returns a trailing logits checksum: without it XLA dead-code-
    eliminates the forward tail (lm_head, final_norm, last down-proj) and
    *prunes those parameters from the compiled executable*, breaking the
    fixed positional ABI the rust runtime feeds. The rust side ignores it.
    """
    captures = {}
    logits = forward(cfg, weights_flat, tokens, captures)
    checksum = jnp.sum(logits)
    return tuple(captures[n] for n, _ in layer_input_spec(cfg)) + (checksum,)


# --------------------------------------------------------------------------
# Batched Hessian contributions (Phase-1 fast path)
# --------------------------------------------------------------------------
#
# Algorithm 1 accumulates Σ_i G[i]^T G[i] per layer over calibration samples.
# Executing fwd+bwd per sample from rust costs one PJRT dispatch + gradient
# download each; these artifacts vmap a whole chunk of B samples and contract
# on-device through the L1 hessian_accum kernel (Σ_b G_b^T G_b equals the
# contraction of the [B*m, n]-stacked gradients), returning only the [n, n]
# Hessian contributions. See EXPERIMENTS.md §Perf.

CALIB_BATCH = 8


def _contract(stacked):
    """Σ_b M_b^T M_b as one [B*m, n] contraction.

    On CPU-PJRT this must be the plain XLA dot: the Pallas kernel only runs
    under interpret=True here, whose grid loops lower to while-loops that
    are ~10x slower than the fused dot (measured — EXPERIMENTS.md §Perf).
    On a real TPU target this call site is where `hessian_accum` (the L1
    kernel, identical math, pinned against it by python/tests) drops in.
    """
    b, m, n = stacked.shape
    g = stacked.reshape(b * m, n)
    return jax.lax.dot_general(
        g, g, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def batch_hessian_oac(cfg, weights_flat, tokens_b):
    """Per-linear-layer Σ_b G_b^T G_b over a [B, seq] token batch."""
    grads_b = jax.vmap(lambda t: linear_grads(cfg, weights_flat, t))(tokens_b)
    return tuple(_contract(g) for g in grads_b)


def batch_hessian_agnostic(cfg, weights_flat, tokens_b):
    """Per-capture Σ_b X_b^T X_b over a [B, seq] token batch (+checksum —
    see layer_inputs for why the trailing scalar exists)."""
    caps_b = jax.vmap(lambda t: layer_inputs(cfg, weights_flat, t))(tokens_b)
    outs = [_contract(x) for x in caps_b[:-1]]
    return tuple(outs) + (jnp.sum(caps_b[-1]),)


# --------------------------------------------------------------------------
# Training step (Adam) — used by the rust training driver for the e2e example
# --------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def train_step(cfg, weights_flat, m_flat, v_flat, step, lr, tokens_batch):
    """One Adam step on a [B, seq] token batch.

    Returns (new_weights..., new_m..., new_v..., mean_loss) flattened.
    """
    def batch_loss(ws):
        per = jax.vmap(lambda t: loss_mean(cfg, ws, t))(tokens_batch)
        return jnp.mean(per)

    loss, grads = jax.value_and_grad(batch_loss)(tuple(weights_flat))
    t = step + 1.0
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    new_w, new_m, new_v = [], [], []
    for w, m, v, g in zip(weights_flat, m_flat, v_flat, grads):
        m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        upd = lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + ADAM_EPS)
        new_w.append(w - upd)
        new_m.append(m2)
        new_v.append(v2)
    return tuple(new_w) + tuple(new_m) + tuple(new_v) + (loss,)

"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references: every Pallas kernel in this package is
asserted allclose against the function of the same name here (see
python/tests/test_kernel.py, which sweeps shapes/dtypes with hypothesis).
"""

import jax.numpy as jnp


def hessian_accum_ref(g, h):
    """Accumulate the output-adaptive Hessian contribution of one gradient
    matrix (paper eq. 14 / 22): ``H <- H + G^T G``.

    Args:
      g: gradient (or activation) matrix, shape [m, n].
      h: running Hessian accumulator, shape [n, n].

    Returns:
      h + g.T @ g, in f32.
    """
    g = g.astype(jnp.float32)
    return h.astype(jnp.float32) + g.T @ g


def qdq_ref(w, group_size, bits):
    """Group-wise asymmetric uniform quantize-dequantize (RTN inner op).

    Groups run along the input (column) dimension of the weight matrix,
    matching SpQR/OPTQ convention. Scale/zero are per (row, group):

      scale = (max - min) / (2^bits - 1),  zero = round(-min / scale)
      q     = clip(round(w / scale) + zero, 0, 2^bits - 1)
      dq    = (q - zero) * scale

    Args:
      w: weight matrix [rows, cols]; cols % group_size == 0.
      group_size: columns per quantization group.
      bits: integer bit width >= 1.

    Returns:
      Dequantized weights, same shape as w, f32.
    """
    rows, cols = w.shape
    assert cols % group_size == 0
    levels = (1 << bits) - 1
    wg = w.astype(jnp.float32).reshape(rows, cols // group_size, group_size)
    lo = jnp.min(wg, axis=-1, keepdims=True)
    hi = jnp.max(wg, axis=-1, keepdims=True)
    rng = hi - lo
    scale = rng / levels
    safe = jnp.where(scale <= 0.0, 1.0, scale)
    zero = jnp.round(-lo / safe)
    q = jnp.clip(jnp.round(wg / safe) + zero, 0.0, float(levels))
    dq = (q - zero) * safe
    # Degenerate all-equal groups: keep the value exactly.
    dq = jnp.where(rng <= 0.0, wg, dq)
    return dq.reshape(rows, cols)

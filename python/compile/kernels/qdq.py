"""L1 Pallas kernel: fused group-wise quantize-dequantize (RTN inner op).

Every calibration backend in the repo (RTN, OPTQ, SpQR, OAC, ...) repeatedly
quantizes weight groups; this kernel is the fused form used on the artifact
path for whole-matrix quant-dequant (e.g. RTN baseline evaluation and the
perf benches).

Layout: groups run along the column (input) axis, scale/zero per (row,
group). Each grid step owns a (block_rows x cols) tile so a full row of
groups is resident in VMEM; min/max/round/clamp are VPU element-wise and
lane-reduction ops — one HBM read + one HBM write per element, i.e. the
kernel is purely bandwidth-bound (arithmetic intensity ~6 flops/byte-read).

interpret=True: see hessian_accum.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, o_ref, *, group_size, bits):
    rows, cols = w_ref.shape
    levels = (1 << bits) - 1
    w = w_ref[...].astype(jnp.float32).reshape(rows, cols // group_size, group_size)
    lo = jnp.min(w, axis=-1, keepdims=True)
    hi = jnp.max(w, axis=-1, keepdims=True)
    rng = hi - lo
    scale = rng / levels
    safe = jnp.where(scale <= 0.0, 1.0, scale)
    zero = jnp.round(-lo / safe)
    q = jnp.clip(jnp.round(w / safe) + zero, 0.0, float(levels))
    dq = jnp.where(rng <= 0.0, w, (q - zero) * safe)
    o_ref[...] = dq.reshape(rows, cols)


def qdq(w, *, group_size, bits, block_rows=64, interpret=True):
    """Pallas fused group quantize-dequantize.

    Args:
      w: [rows, cols] weights; cols % group_size == 0.
      group_size: columns per group (paper uses 16-128).
      bits: bit width (static).

    Returns: dequantized [rows, cols] f32.
    """
    rows, cols = w.shape
    assert cols % group_size == 0, (w.shape, group_size)
    br = min(block_rows, rows)
    while rows % br != 0:
        br -= 1

    return pl.pallas_call(
        functools.partial(_kernel, group_size=group_size, bits=bits),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(w)

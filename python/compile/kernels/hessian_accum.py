"""L1 Pallas kernel: tiled output-adaptive Hessian accumulation, H += G^T G.

This is the compute hot-spot of OAC Phase 1 (paper eqs. 13-14/22): for every
calibration sample i and every linear layer, the gradient matrix G[i]
(d_row x d_col) contributes G[i]^T G[i] to the layer's aggregated
output-adaptive Hessian. The same kernel also serves the output-agnostic
baselines' Hessian (X^T X over layer inputs, eq. 1) since it is the identical
contraction with G replaced by the activation matrix.

Hardware adaptation (paper used CUDA GEMMs): the contraction is expressed as
an MXU-shaped tiled matmul. Grid = (n/bn, n/bn, m/bk); for output tile (i, j)
the kernel streams A = G[k, i-tile] and B = G[k, j-tile] blocks HBM->VMEM and
accumulates into the resident H tile in f32. The k axis is innermost so each
output tile is revisited across k steps while staying in VMEM (double
buffering of the G tiles is left to the Mosaic pipeliner via BlockSpec).

VMEM footprint per step: bk*bn (A) + bk*bn (B) + bn*bn (acc) f32 words.
With bn = bk = 128 that is 3 * 64 KiB = 192 KiB << 16 MiB VMEM, leaving room
for the pipeline's double buffers; the MXU sees (bk x bn)^T @ (bk x bn)
= 128^3 MACs per step, i.e. full systolic-array tiles.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel is lowered through the interpreter into plain HLO
(see DESIGN.md §3 / §8 for the real-TPU estimate).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_tile(dim, preferred=128):
    """Largest divisor of `dim` that is <= preferred (tiles must divide)."""
    t = min(preferred, dim)
    while dim % t != 0:
        t -= 1
    return t


def _kernel(g_a_ref, g_b_ref, h_in_ref, o_ref, *, k_steps):
    """One (i, j, k) grid step: o[i,j] (+)= A_k^T B_k, seeded with h_in."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = h_in_ref[...]

    a = g_a_ref[...].astype(jnp.float32)  # [bk, bn] rows of G, cols of tile i
    b = g_b_ref[...].astype(jnp.float32)  # [bk, bn] rows of G, cols of tile j
    o_ref[...] += jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def hessian_accum(g, h, *, block_n=128, block_k=128, interpret=True):
    """Pallas tiled ``h + g.T @ g``.

    Args:
      g: [m, n] gradient/activation matrix (f32 or bf16).
      h: [n, n] f32 accumulator.
      block_n / block_k: preferred tile sizes (clamped to divisors).

    Returns: [n, n] f32.
    """
    m, n = g.shape
    assert h.shape == (n, n), (g.shape, h.shape)
    bn = _pick_tile(n, block_n)
    bk = _pick_tile(m, block_k)
    k_steps = m // bk
    grid = (n // bn, n // bn, k_steps)

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, i)),  # A: G[k, i]
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),  # B: G[k, j]
            pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),  # H_in
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(g, g, h)  # g appears twice: once per side of the G^T G contraction

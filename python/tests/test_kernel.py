"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

This is the CORE correctness signal for the kernel layer. hypothesis sweeps
shapes and dtypes; every case asserts allclose against the oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.hessian_accum import hessian_accum
from compile.kernels.qdq import qdq
from compile.kernels.ref import hessian_accum_ref, qdq_ref

jax.config.update("jax_enable_x64", False)


def rand(shape, seed, dtype=jnp.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# ----------------------------------------------------------------- hessian

dims = st.sampled_from([8, 16, 32, 64, 96, 128, 160, 256])


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_hessian_accum_matches_ref(m, n, seed, dtype):
    g = rand((m, n), seed, dtype)
    h = rand((n, n), seed + 1)
    got = hessian_accum(g, h)
    want = hessian_accum_ref(g, h)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-4)


@settings(max_examples=10, deadline=None)
@given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_hessian_accum_accumulates(m, n, seed):
    """Two sequential accumulations == sum of contributions (eq. 22)."""
    g1 = rand((m, n), seed)
    g2 = rand((m, n), seed + 7)
    h0 = jnp.zeros((n, n), jnp.float32)
    h = hessian_accum(g2, hessian_accum(g1, h0))
    want = hessian_accum_ref(g2, hessian_accum_ref(g1, h0))
    np.testing.assert_allclose(np.asarray(h), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_hessian_accum_non_preferred_tiles():
    """Dims that don't divide 128 must still tile correctly."""
    g = rand((48, 80), 0)
    h = rand((80, 80), 1)
    np.testing.assert_allclose(
        np.asarray(hessian_accum(g, h, block_n=32, block_k=32)),
        np.asarray(hessian_accum_ref(g, h)), rtol=1e-5, atol=1e-5)


def test_hessian_accum_psd():
    """Starting from zero, the accumulated Hessian is PSD."""
    g = rand((64, 32), 3)
    h = hessian_accum(g, jnp.zeros((32, 32), jnp.float32))
    eig = np.linalg.eigvalsh(np.asarray(h))
    assert eig.min() > -1e-4


# --------------------------------------------------------------------- qdq

@settings(max_examples=25, deadline=None)
@given(rows=st.sampled_from([4, 16, 33, 64, 128]),
       groups=st.integers(1, 8),
       group_size=st.sampled_from([4, 8, 16, 32]),
       bits=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
def test_qdq_matches_ref(rows, groups, group_size, bits, seed):
    w = rand((rows, groups * group_size), seed, scale=0.5)
    got = qdq(w, group_size=group_size, bits=bits)
    want = qdq_ref(w, group_size, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(bits=st.integers(2, 4), seed=st.integers(0, 2**31 - 1))
def test_qdq_level_count(bits, seed):
    """Dequantized groups use at most 2^bits distinct values."""
    w = rand((8, 32), seed)
    dq = np.asarray(qdq(w, group_size=16, bits=bits))
    for r in range(8):
        for g in range(2):
            vals = np.unique(dq[r, g * 16:(g + 1) * 16])
            assert len(vals) <= (1 << bits)


def test_qdq_error_shrinks_with_bits():
    w = rand((32, 64), 11)
    errs = []
    for bits in (1, 2, 3, 4):
        dq = np.asarray(qdq(w, group_size=16, bits=bits))
        errs.append(np.abs(dq - np.asarray(w)).mean())
    assert errs == sorted(errs, reverse=True)


def test_qdq_constant_group_exact():
    """All-equal groups round-trip exactly (degenerate scale guard)."""
    w = jnp.full((4, 16), 0.37, jnp.float32)
    dq = qdq(w, group_size=16, bits=2)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(w), atol=1e-7)

"""L2 correctness: transformer shapes, loss, gradient and capture checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.get_config("tiny")


@pytest.fixture(scope="module")
def weights():
    return M.init_weights(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    return jnp.asarray(
        np.random.default_rng(0).integers(0, CFG.vocab, CFG.seq), jnp.int32)


def test_forward_shape(weights, tokens):
    logits = M.forward(CFG, weights, tokens)
    assert logits.shape == (CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_close_to_uniform_at_init(weights, tokens):
    """Random init => per-token CE near log(vocab)."""
    mean = float(M.loss_mean(CFG, weights, tokens))
    assert abs(mean - np.log(CFG.vocab)) < 1.0


def test_causality(weights, tokens):
    """Changing a future token must not affect earlier logits."""
    logits = M.forward(CFG, weights, tokens)
    tok2 = tokens.at[-1].set((tokens[-1] + 1) % CFG.vocab)
    logits2 = M.forward(CFG, weights, tok2)
    np.testing.assert_allclose(np.asarray(logits[:-1]),
                               np.asarray(logits2[:-1]), rtol=1e-5, atol=1e-5)


def test_linear_grads_shapes(weights, tokens):
    grads = M.linear_grads(CFG, weights, tokens)
    spec = M.linear_layer_spec(CFG)
    assert len(grads) == len(spec) == CFG.n_layers * 6
    for g, (_, shape, _, _) in zip(grads, spec):
        assert g.shape == shape


def test_linear_grads_match_full_grad(weights, tokens):
    """Grad through the flat-tuple wrapper equals grad of the plain loss."""
    names = [n for n, _ in M.weight_spec(CFG)]
    idx = names.index("blocks.0.q")
    full = jax.grad(
        lambda w: M.loss_mean(CFG, tuple(w), tokens), argnums=0)(tuple(weights))
    grads = M.linear_grads(CFG, weights, tokens)
    np.testing.assert_allclose(np.asarray(full[idx]), np.asarray(grads[0]),
                               rtol=1e-5, atol=1e-6)


def test_layer_inputs_shapes_and_semantics(weights, tokens):
    caps = M.layer_inputs(CFG, weights, tokens)[:-1]  # drop the checksum
    spec = M.layer_input_spec(CFG)
    assert len(caps) == len(spec) == CFG.n_layers * 4
    for c, (_, shape) in zip(caps, spec):
        assert c.shape == shape
    # x_attn of block 0 is the RMS-normed embedding stream: verify directly.
    w = M.unflatten(CFG, weights)
    hdn = w["embed"][tokens] + w["pos_embed"]
    var = jnp.mean(hdn * hdn, axis=-1, keepdims=True)
    x_attn0 = hdn * jax.lax.rsqrt(var + 1e-5) * w["blocks.0.attn_norm"]
    np.testing.assert_allclose(np.asarray(caps[0]), np.asarray(x_attn0),
                               rtol=1e-5, atol=1e-5)


def test_hessian_from_grads_psd(weights, tokens):
    """Sum G^T G over samples is PSD — the property eq. 8 relies on."""
    grads = M.linear_grads(CFG, weights, tokens)
    g = np.asarray(grads[0])
    h = g.T @ g
    assert np.linalg.eigvalsh(h).min() > -1e-8


def test_train_step_reduces_loss(weights):
    rng = np.random.default_rng(1)
    # A highly regular corpus: the model should fit it within a few steps.
    batch = np.tile(np.arange(CFG.seq) % 7, (CFG.train_batch, 1))
    batch = jnp.asarray(batch, jnp.int32)
    ws = list(weights)
    ms = [jnp.zeros_like(x) for x in ws]
    vs = [jnp.zeros_like(x) for x in ws]
    losses = []
    step_fn = jax.jit(lambda w, m, v, s, b: M.train_step(
        CFG, w, m, v, s, jnp.float32(1e-3), b))
    n = len(ws)
    for s in range(8):
        out = step_fn(tuple(ws), tuple(ms), tuple(vs), jnp.float32(s), batch)
        ws, ms, vs = list(out[:n]), list(out[n:2 * n]), list(out[2 * n:3 * n])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_weight_spec_consistency():
    for name in M.CONFIGS:
        cfg = M.get_config(name)
        spec = M.weight_spec(cfg)
        assert len(spec) == 2 + 8 * cfg.n_layers + 2
        lin = M.linear_layer_spec(cfg)
        wnames = {n for n, _ in spec}
        for n, shape, inp, blk in lin:
            assert n in wnames
            assert 0 <= blk < cfg.n_layers


def test_batch_hessian_oac_matches_per_sample(weights, tokens):
    """The batched Phase-1 artifact function equals Σ_b G_b^T G_b."""
    import numpy as np
    tokens_b = jnp.stack([tokens, (tokens + 1) % CFG.vocab])
    batched = M.batch_hessian_oac(CFG, weights, tokens_b)
    spec = M.linear_layer_spec(CFG)
    assert len(batched) == len(spec)
    g0 = M.linear_grads(CFG, weights, tokens_b[0])
    g1 = M.linear_grads(CFG, weights, tokens_b[1])
    for bh, a, b in zip(batched, g0, g1):
        want = np.asarray(a).T @ np.asarray(a) + np.asarray(b).T @ np.asarray(b)
        np.testing.assert_allclose(np.asarray(bh), want, rtol=2e-3, atol=1e-5)


def test_batch_hessian_agnostic_matches_per_sample(weights, tokens):
    import numpy as np
    tokens_b = jnp.stack([tokens, (tokens + 3) % CFG.vocab])
    batched = M.batch_hessian_agnostic(CFG, weights, tokens_b)
    caps0 = M.layer_inputs(CFG, weights, tokens_b[0])
    caps1 = M.layer_inputs(CFG, weights, tokens_b[1])
    spec = M.layer_input_spec(CFG)
    assert len(batched) == len(spec) + 1  # + checksum
    for bh, a, b in zip(batched[:-1], caps0[:-1], caps1[:-1]):
        want = np.asarray(a).T @ np.asarray(a) + np.asarray(b).T @ np.asarray(b)
        np.testing.assert_allclose(np.asarray(bh), want, rtol=2e-3, atol=1e-4)
